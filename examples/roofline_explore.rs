//! Interactive exploration of the roofline performance model (§3.3):
//! query prefill/decode latency, bottleneck classification, bs_sat, and
//! KV capacity for any model/hardware pair.
//!
//! ```bash
//! cargo run --release --example roofline_explore -- --model 7b --hw 910c \
//!     --batch 128 --kv-len 1000 --prompt 1892
//! ```

use ooco::config::{HardwareProfile, ModelSpec};
use ooco::perfmodel::{BatchStats, PerfModel};
use ooco::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let model = args.str("model", "7b").parse::<ModelSpec>()?;
    let hw = args.str("hw", "910c").parse::<HardwareProfile>()?;
    let batch = args.usize("batch", 128);
    let kv_len = args.usize("kv-len", 1000);
    let prompt = args.usize("prompt", 1892);

    let pm = PerfModel::new(model.clone(), hw.clone());
    println!("model {} on {}", model.name, hw.name);
    println!("  params            {:.2} B", model.param_count() / 1e9);
    println!("  weights           {:.1} GB", model.weights_bytes() / 1e9);
    println!("  kv bytes/token    {:.0} B", model.kv_bytes_per_token());
    println!("  kv capacity       {} tokens", pm.max_kv_tokens());
    println!("  bs_sat            {} (compute-saturated batch)", pm.bs_sat());
    println!();

    let pc = pm.prefill_cost(&[prompt]);
    println!("prefill({prompt} tokens):");
    println!("  latency           {:.2} ms", pc.latency_s * 1e3);
    println!("  flops             {:.2} TFLOP", pc.total_flops() / 1e12);
    println!("  achieved          {:.1} TFLOP/s", pc.achieved_flops() / 1e12);
    println!("  intensity         {:.1} FLOP/B", pc.intensity());
    println!();

    let b = BatchStats::new(batch, batch * kv_len);
    let dc = pm.decode_cost(b);
    println!("decode(batch={batch}, kv_len={kv_len}):");
    println!("  latency           {:.2} ms", dc.latency_s * 1e3);
    println!("  bottleneck        {:?}", pm.decode_bottleneck(b));
    println!("  memory util       {:.1}%", pm.memory_utilization(b) * 100.0);
    println!("  achieved          {:.1} TFLOP/s", dc.achieved_flops() / 1e12);
    println!("  intensity         {:.1} FLOP/B", dc.intensity());
    println!(
        "  kv transfer       {:.2} ms ({} tokens over RDMA)",
        pm.kv_transfer_latency(kv_len) * 1e3,
        kv_len
    );
    Ok(())
}
