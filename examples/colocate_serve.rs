//! End-to-end co-located serving driver (DESIGN.md §6): loads the real
//! AOT-compiled tiny model, replays a mixed online+offline trace through
//! the OOCO engine (Algorithm 2 batching on calibrated perf-model
//! predictions), and reports TTFT/TPOT percentiles, SLO violations, and
//! online/offline token throughput. Optionally compares all three policies.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example colocate_serve -- \
//!     --duration 20 --online-rate 1.0 --offline-qps 1.0 --compare
//! ```

use ooco::coordinator::Policy;
use ooco::engine::{serve_trace_with_runtime, EngineConfig};
use ooco::runtime::Runtime;
use ooco::trace::datasets::{DatasetProfile, LengthProfile};
use ooco::trace::generator::{offline_trace, online_trace};
use ooco::trace::Trace;
use ooco::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let duration = args.f64("duration", 20.0);
    let online_rate = args.f64("online-rate", 1.0);
    let offline_qps = args.f64("offline-qps", 1.0);
    let compare = args.has("compare");
    let seed = args.u64("seed", 42);

    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        anyhow::bail!("artifacts not built — run `make artifacts` first");
    }
    println!("loading runtime...");
    let rt = Runtime::load(dir)?;

    // Tiny-model-scale trace: dataset shapes from the paper's Table 5
    // profiles, lengths rescaled to the tiny model's context budget.
    let trace = tiny_trace(&rt, online_rate, offline_qps, duration, seed);
    println!(
        "trace: {} online + {} offline requests over {:.0}s",
        trace.count_class(ooco::request::Class::Online),
        trace.count_class(ooco::request::Class::Offline),
        duration
    );

    let policies: Vec<Policy> = if compare {
        Policy::all().to_vec()
    } else {
        vec![Policy::Ooco]
    };
    for policy in policies {
        let cfg = EngineConfig {
            policy,
            max_output: 16,
            seed,
            ..Default::default()
        };
        let out = serve_trace_with_runtime(&rt, &trace, &cfg)?;
        let r = &out.report;
        println!("\n=== policy {} (wall {:.1}s) ===", policy.name(), out.wall_s);
        println!("  {}", r.summary_line());
        println!(
            "  prefills {} | strict steps {} | relaxed steps {} | online tok {} | offline tok {}",
            out.prefills,
            out.strict_steps,
            out.relaxed_steps,
            out.online_tokens,
            out.offline_tokens
        );
        println!(
            "  online {:.1} tok/s wall, offline {:.1} tok/s wall",
            out.online_tokens as f64 / out.wall_s,
            out.offline_tokens as f64 / out.wall_s
        );
    }
    Ok(())
}

fn tiny_trace(
    rt: &Runtime,
    online_rate: f64,
    offline_qps: f64,
    duration: f64,
    seed: u64,
) -> Trace {
    // Rescale the Table 5 length profiles into the tiny model's context:
    // prompts up to ~smax/2, outputs capped by the engine's max_output.
    let max_prompt = rt.manifest.smax / 2;
    let mut online_ds = DatasetProfile::azure_conv();
    online_ds.prompt = LengthProfile::new(96.0, 0.6, 8, max_prompt);
    online_ds.output = LengthProfile::new(10.0, 0.5, 1, 16);
    let mut offline_ds = DatasetProfile::ooc_offline();
    offline_ds.prompt = LengthProfile::new(128.0, 0.6, 8, max_prompt);
    offline_ds.output = LengthProfile::new(12.0, 0.5, 1, 16);

    online_trace(online_ds, online_rate, duration, seed)
        .merge(offline_trace(offline_ds, offline_qps, duration, seed + 1))
}
