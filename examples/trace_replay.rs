//! Full-scale trace replay through the discrete-event simulator: Qwen2.5
//! 7B/72B on 910c-like hardware, comparing the three policies on one
//! dataset configuration (the per-point view of Fig. 6).
//!
//! ```bash
//! cargo run --release --example trace_replay -- \
//!     --model 7b --dataset azure-conv --online-rate 0.5 \
//!     --offline-qps 10 --duration 1800
//! ```

use ooco::config::{ModelSpec, ServingConfig};
use ooco::coordinator::Policy;
use ooco::sim::{simulate, SimConfig};
use ooco::trace::datasets::DatasetProfile;
use ooco::trace::generator::{offline_trace, online_trace};
use ooco::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let model = args.str("model", "7b");
    let dataset = args.str("dataset", "azure-conv");
    let online_rate = args.f64("online-rate", 0.5);
    let offline_qps = args.f64("offline-qps", 10.0);
    let duration = args.f64("duration", 1800.0);
    let seed = args.u64("seed", 42);

    let online_ds = DatasetProfile::by_name(dataset)?;
    let offline_ds = DatasetProfile::ooc_offline();
    let trace = online_trace(online_ds, online_rate, duration, seed)
        .merge(offline_trace(offline_ds, offline_qps, duration, seed + 1));
    println!(
        "trace: {} online + {} offline over {:.0}s ({} model, online {:.2} rps, offline {:.2} qps)",
        trace.count_class(ooco::request::Class::Online),
        trace.count_class(ooco::request::Class::Offline),
        duration,
        model,
        online_rate,
        offline_qps,
    );

    let mut serving = ServingConfig::preset_7b();
    serving.model = model.parse::<ModelSpec>()?;

    println!(
        "{:<16} {:>8} {:>10} {:>10} {:>10} {:>8} {:>8} {:>8}",
        "policy", "viol%", "ttft_p99", "tpot_p99", "off_tok/s", "mig", "evict", "preempt"
    );
    for policy in Policy::all() {
        let mut cfg = SimConfig::new(serving.clone(), policy);
        cfg.seed = seed;
        let t0 = std::time::Instant::now();
        let res = simulate(&trace, &cfg);
        let r = &res.report;
        println!(
            "{:<16} {:>7.2}% {:>9.3}s {:>8.1}ms {:>10.1} {:>8} {:>8} {:>8}   [{:.1}s wall]",
            policy.name(),
            r.online_violation_rate * 100.0,
            r.ttft.p99,
            r.tpot.p99 * 1e3,
            r.offline_token_throughput,
            res.migrations,
            res.evictions,
            res.preemptions,
            t0.elapsed().as_secs_f64(),
        );
    }
    Ok(())
}
