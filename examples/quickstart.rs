//! Quickstart: load the AOT artifacts and run one request end-to-end —
//! prefill on the "latency-relaxed" path, then a few decode steps.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use ooco::runtime::{DecodeEntry, Runtime};

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        anyhow::bail!("artifacts not built — run `make artifacts` first");
    }
    println!("loading artifacts (compiling all bucket executables)...");
    let t0 = std::time::Instant::now();
    let rt = Runtime::load(dir)?;
    println!(
        "runtime ready in {:.1}s: model hidden={} layers={} vocab={} smax={}",
        t0.elapsed().as_secs_f64(),
        rt.manifest.hidden,
        rt.manifest.layers,
        rt.manifest.vocab,
        rt.manifest.smax
    );

    // A synthetic prompt (the tiny model has synthetic weights + vocab).
    let prompt: Vec<i32> = (0..48).map(|i| (i * 7 + 3) % 512).collect();
    let t0 = std::time::Instant::now();
    let out = rt.prefill(&prompt)?;
    println!(
        "prefill: {} tokens in {:.1} ms (bucket {})",
        prompt.len(),
        t0.elapsed().as_secs_f64() * 1e3,
        rt.prefill_bucket(prompt.len())?
    );

    let mut kv = out.kv;
    let mut token = argmax(&out.logits);
    let mut pos = prompt.len() as i32;
    print!("generated tokens:");
    let t0 = std::time::Instant::now();
    for _ in 0..12 {
        let mut entries = [DecodeEntry {
            token,
            position: pos,
            kv: &mut kv,
        }];
        let logits = rt.decode(&mut entries)?;
        token = argmax(&logits[0]);
        pos += 1;
        print!(" {token}");
    }
    println!();
    println!(
        "12 decode steps in {:.1} ms ({:.1} ms/step)",
        t0.elapsed().as_secs_f64() * 1e3,
        t0.elapsed().as_secs_f64() * 1e3 / 12.0
    );
    println!("quickstart OK");
    Ok(())
}

fn argmax(logits: &[f32]) -> i32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0 as i32
}
