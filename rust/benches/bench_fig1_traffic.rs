//! Figure 1 reproduction: request-traffic variation patterns across the
//! three datasets — hour/day-scale tide plus minute-scale bursty spikes.
//!
//! Prints a per-minute request-rate series (downsampled) plus the summary
//! statistics that make the fluctuation structure visible in text form:
//! peak/trough ratio at hour scale (tide) and max/median ratio at minute
//! scale (bursts).

use ooco::trace::datasets::DatasetProfile;
use ooco::trace::generator::online_trace;
use ooco::util::cli::Args;

fn main() {
    let args = Args::parse_env();
    let duration = args.f64("duration", 86_400.0); // one day
    let rate = args.f64("rate", 2.0);
    let seed = args.u64("seed", 42);

    println!("=== Figure 1: traffic fluctuation patterns ===");
    println!("(synthetic traces matching the published datasets' structure)\n");

    for ds in [
        DatasetProfile::ooc_online(),
        DatasetProfile::azure_conv(),
        DatasetProfile::azure_code(),
    ] {
        let trace = online_trace(ds.clone(), rate, duration, seed);
        let minute = trace.rate_series(60.0);
        let hour = trace.rate_series(3600.0);

        let mut sorted_min: Vec<usize> = minute.clone();
        sorted_min.sort_unstable();
        let med_min = sorted_min[sorted_min.len() / 2] as f64;
        let max_min = *sorted_min.last().unwrap() as f64;
        let peak_hr = *hour.iter().max().unwrap() as f64;
        let trough_hr = *hour.iter().min().unwrap() as f64;

        println!(
            "--- {} ({} requests over {:.0} h) ---",
            ds.name,
            trace.len(),
            duration / 3600.0
        );
        println!(
            "  hour-scale tide:    peak {:.0}/h, trough {:.0}/h, ratio {:.2}x",
            peak_hr,
            trough_hr,
            peak_hr / trough_hr.max(1.0)
        );
        println!(
            "  minute-scale burst: max {:.0}/min vs median {:.0}/min, ratio {:.2}x",
            max_min,
            med_min,
            max_min / med_min.max(1.0)
        );
        // ASCII sparkline of the hourly series.
        print!("  hourly series:      ");
        let max = peak_hr.max(1.0);
        for &h in &hour {
            let lvl = (h as f64 / max * 7.0).round() as usize;
            print!("{}", ['.', ':', '-', '=', '+', '*', '#', '@'][lvl.min(7)]);
        }
        println!();
        // Downsampled minute series around the burstiest window.
        let peak_idx = minute
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(i, _)| i)
            .unwrap_or(0);
        let lo = peak_idx.saturating_sub(15);
        let hi = (peak_idx + 15).min(minute.len());
        print!("  burst window (min {lo}-{hi}): ");
        for &c in &minute[lo..hi] {
            print!("{c} ");
        }
        println!("\n");
    }
}
