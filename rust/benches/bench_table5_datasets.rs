//! Table 5 reproduction: average prompt and output lengths across the four
//! dataset profiles. Generates a large sample from each synthetic profile
//! and compares against the published means.

use ooco::trace::datasets::DatasetProfile;
use ooco::trace::generator::{offline_trace, online_trace};
use ooco::util::cli::Args;

fn main() {
    let args = Args::parse_env();
    let n_target = args.usize("samples", 30_000);
    let seed = args.u64("seed", 42);

    println!("=== Table 5: average prompt / output lengths ===");
    println!(
        "{:<16} {:>14} {:>14} {:>14} {:>14} {:>8}",
        "dataset", "paper prompt", "ours prompt", "paper output", "ours output", "err%"
    );

    let rows: Vec<(&str, DatasetProfile, f64, f64)> = vec![
        ("OOC (Online)", DatasetProfile::ooc_online(), 1892.47, 1062.62),
        ("OOC (Offline)", DatasetProfile::ooc_offline(), 1200.52, 671.51),
        ("Azure Conv", DatasetProfile::azure_conv(), 1512.30, 98.75),
        ("Azure Code", DatasetProfile::azure_code(), 2317.18, 22.74),
    ];

    for (name, ds, paper_p, paper_o) in rows {
        // Enough duration at a fixed rate to collect ~n_target samples.
        let rate = 5.0;
        let duration = n_target as f64 / rate;
        let trace = if name.contains("Offline") {
            offline_trace(ds, rate, duration, seed)
        } else {
            online_trace(ds, rate, duration, seed)
        };
        let (p, o) = trace.mean_lengths(None);
        let err = ((p / paper_p - 1.0).abs()).max((o / paper_o - 1.0).abs());
        println!(
            "{:<16} {:>14.2} {:>14.2} {:>14.2} {:>14.2} {:>7.1}%",
            name,
            paper_p,
            p,
            paper_o,
            o,
            err * 100.0
        );
    }
    println!("\n(lognormal sampling targets the published arithmetic means;");
    println!(" residual error is clamping of the extreme tail)");
}
