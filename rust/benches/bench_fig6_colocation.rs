//! Figure 6 reproduction — the paper's headline experiment.
//!
//! For each (dataset, model) combination:
//!   1. calibrate the online traffic scale so the pure-online system just
//!      meets the SLO at the traffic peak (§5.2);
//!   2. sweep offline QPS from ~zero upward for the three systems
//!      (base P/D, online priority, OOCO);
//!   3. report the online SLO violation rate at each level, the max
//!      effective offline throughput per system, and OOCO's improvement
//!      over the best baseline (paper: 1.17x–3x).
//!
//! Flags: --quick (shorter sims, 7B only), --duration, --levels, --seed.

use ooco::config::{ModelSpec, ServingConfig};
use ooco::coordinator::Policy;
use ooco::sweep::{
    find_online_capacity, max_effective_offline, offline_sweep, qps_grid,
    SweepConfig,
};
use ooco::trace::datasets::DatasetProfile;
use ooco::util::cli::Args;

fn main() {
    let args = Args::parse_env();
    let quick = args.has("quick");
    let duration = args.f64("duration", if quick { 600.0 } else { 1800.0 });
    let levels = args.usize("levels", if quick { 5 } else { 7 });
    let seed = args.u64("seed", 42);

    let models: Vec<ModelSpec> = if quick {
        vec![ModelSpec::qwen2_5_7b()]
    } else {
        vec![ModelSpec::qwen2_5_7b(), ModelSpec::qwen2_5_72b()]
    };

    println!("=== Figure 6: online-offline co-location service experiment ===");
    println!("(violation threshold 3%; offline = OOC offline pool everywhere)\n");

    for model in &models {
        for (ds_name, online_ds, offline_ds) in DatasetProfile::experiment_pairs() {
            let mut serving = ServingConfig::preset_7b();
            serving.model = model.clone();
            let sweep = SweepConfig {
                duration_s: duration,
                seed,
                ..Default::default()
            };

            // Step 1: pure-online capacity.
            let cap = find_online_capacity(&serving, &online_ds, &sweep);
            println!(
                "--- {} x {} | online capacity {:.2} req/s ---",
                model.name, ds_name, cap
            );

            // Step 2: offline sweep per policy.
            let grid = {
                let mut g = vec![0.25f64];
                g.extend(qps_grid(0.5, 40.0, levels));
                g
            };
            let mut max_eff = Vec::new();
            for policy in Policy::all() {
                let pts = offline_sweep(
                    &serving,
                    policy,
                    &online_ds,
                    cap,
                    &offline_ds,
                    &grid,
                    &sweep,
                );
                println!("  policy {:<16}", policy.name());
                println!(
                    "    {:>8} {:>8} {:>12} {:>10} {:>10}",
                    "qps", "viol%", "off tok/s", "ttft p99", "tpot p99"
                );
                for p in &pts {
                    println!(
                        "    {:>8.2} {:>7.2}% {:>12.1} {:>9.2}s {:>8.1}ms",
                        p.offline_qps,
                        p.violation_rate * 100.0,
                        p.offline_token_throughput,
                        p.ttft_p99,
                        p.tpot_p99 * 1e3,
                    );
                }
                let eff = max_effective_offline(
                    &pts,
                    serving.slo.violation_threshold,
                );
                println!("    => max effective offline throughput {eff:.1} tok/s");
                max_eff.push(eff);
            }

            // Step 3: improvement factor.
            let best_baseline = max_eff[0].max(max_eff[1]).max(1e-9);
            println!(
                "  OOCO improvement over best baseline: {:.2}x  (paper: 1.17x-3x)\n",
                max_eff[2] / best_baseline
            );
        }
    }
}
