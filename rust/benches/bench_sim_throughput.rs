//! Simulator-throughput bench: simulated requests per wall-clock second on
//! a 100k-request co-locate trace, with chunking on and off — the metric
//! that keeps simulator speed on the scaling trajectory (the hot-loop
//! scratch-buffer work in `scheduler::core` lands here) — plus a
//! calendar-vs-binary-heap queue comparison row (same run, swapped event
//! queue, byte-identical report — DESIGN.md §3.13), a million-request
//! scaling point (same steady-state load over 10x the span; near-constant
//! sim req/s is the calendar queue's O(1)-amortized claim made visible;
//! skip with `--million false`), and a flight-recorder point that prices
//! telemetry against the disabled recorder the first two runs pay
//! (DESIGN.md §3.10).
//!
//! Run: `cargo bench --bench bench_sim_throughput` (plain binary, no
//! harness).

use std::time::Instant;

use ooco::config::{ChunkMode, ServingConfig};
use ooco::coordinator::Policy;
use ooco::sim::{
    simulate, simulate_queued, simulate_traced, QueueKind, SimConfig,
};
use ooco::telemetry::TelemetryOpts;
use ooco::trace::datasets::{DatasetProfile, LengthProfile};
use ooco::trace::generator::{offline_trace, online_trace};
use ooco::trace::Trace;
use ooco::util::cli::Args;
use ooco::util::json::Json;

/// ~100k requests: steady co-locate load with short outputs so the run is
/// step-dense but bounded.
fn trace_100k() -> Trace {
    let duration = 4000.0;
    let mut online_ds = DatasetProfile::azure_conv();
    online_ds.prompt = LengthProfile::new(900.0, 0.8, 32, 8192);
    online_ds.output = LengthProfile::new(24.0, 0.6, 1, 96);
    let mut offline_ds = DatasetProfile::ooc_offline();
    offline_ds.prompt = LengthProfile::new(1100.0, 0.8, 32, 8192);
    offline_ds.output = LengthProfile::new(32.0, 0.6, 1, 128);
    // 15 online/s + 10 offline/s over 4000 s ≈ 100k requests.
    let online = online_trace(online_ds, 15.0, duration, 4242);
    let offline = offline_trace(offline_ds, 10.0, duration, 4243);
    online.merge(offline)
}

/// ~1M requests: the same steady-state load as [`trace_100k`] over 10x
/// the span, so the scaling point isolates queue/metrics growth effects
/// (a longer run, not a denser one).
fn trace_1m() -> Trace {
    let duration = 40_000.0;
    let mut online_ds = DatasetProfile::azure_conv();
    online_ds.prompt = LengthProfile::new(900.0, 0.8, 32, 8192);
    online_ds.output = LengthProfile::new(24.0, 0.6, 1, 96);
    let mut offline_ds = DatasetProfile::ooc_offline();
    offline_ds.prompt = LengthProfile::new(1100.0, 0.8, 32, 8192);
    offline_ds.output = LengthProfile::new(32.0, 0.6, 1, 128);
    let online = online_trace(online_ds, 15.0, duration, 4252);
    let offline = offline_trace(offline_ds, 10.0, duration, 4253);
    online.merge(offline)
}

fn bench_cfg() -> SimConfig {
    let mut serving = ServingConfig::preset_7b();
    serving.cluster.relaxed_instances = 4;
    serving.cluster.strict_instances = 4;
    serving.chunk_tokens = ChunkMode::Auto;
    let mut cfg = SimConfig::new(serving, Policy::Ooco);
    cfg.drain_s = 600.0;
    cfg
}

fn main() {
    let args = Args::parse_env();
    let trace = trace_100k();
    println!(
        "trace: {} requests ({} online / {} offline), {:.0} s span",
        trace.len(),
        trace.count_class(ooco::request::Class::Online),
        trace.count_class(ooco::request::Class::Offline),
        trace.duration()
    );

    let mut points = Vec::new();
    let mut chunked_baseline: Option<(f64, String)> = None;
    for (label, mode) in [
        ("chunked (auto)", ChunkMode::Auto),
        ("exclusive (off)", ChunkMode::Off),
    ] {
        let mut serving = ServingConfig::preset_7b();
        serving.cluster.relaxed_instances = 4;
        serving.cluster.strict_instances = 4;
        serving.chunk_tokens = mode;
        let mut cfg = SimConfig::new(serving, Policy::Ooco);
        cfg.drain_s = 600.0;
        let t0 = Instant::now();
        let res = simulate(&trace, &cfg);
        let wall = t0.elapsed().as_secs_f64();
        let req_per_s = trace.len() as f64 / wall.max(1e-9);
        println!(
            "{label:>16}: {wall:6.2} s wall | {req_per_s:9.0} sim req/s | {}",
            res.report.summary_line()
        );
        println!("{:>16}  {}", "", res.chunk.summary_line());
        points.push(Json::obj(vec![
            ("label", Json::Str(label.into())),
            ("wall_s", Json::Num(wall)),
            ("sim_req_per_s", Json::Num(req_per_s)),
            ("report", res.report.to_json()),
            ("chunk", res.chunk.to_json()),
        ]));
        if matches!(mode, ChunkMode::Auto) {
            chunked_baseline =
                Some((wall, res.report.to_json().to_string()));
        }
    }

    let (base_wall, base_report) =
        chunked_baseline.expect("chunked point ran");

    // Calendar-vs-heap comparison (DESIGN.md §3.13): the same chunked
    // run on the explicit binary-heap event queue. Both queues honor the
    // identical (time, insertion-order) contract, so the report must be
    // byte-identical — the only thing a queue swap may change is wall
    // time, and the ratio lands in the artifact.
    {
        let cfg = bench_cfg();
        let t0 = Instant::now();
        let res =
            simulate_queued(&trace, &cfg, None, false, QueueKind::BinaryHeap);
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(
            base_report,
            res.report.to_json().to_string(),
            "queue swap perturbed the simulation"
        );
        let calendar_speedup = wall / base_wall.max(1e-9);
        println!(
            "{:>16}: {wall:6.2} s wall | {:9.0} sim req/s | calendar is {calendar_speedup:.2}x faster",
            "binary heap",
            trace.len() as f64 / wall.max(1e-9),
        );
        points.push(Json::obj(vec![
            ("label", Json::Str("binary heap".into())),
            ("wall_s", Json::Num(wall)),
            (
                "sim_req_per_s",
                Json::Num(trace.len() as f64 / wall.max(1e-9)),
            ),
            ("calendar_speedup", Json::Num(calendar_speedup)),
        ]));
    }

    // Million-request scaling point: near-constant sim req/s from 100k
    // to 1M is the O(1)-amortized event-queue + streaming-metrics claim.
    if args.bool("million", true) {
        let t1m = trace_1m();
        let cfg = bench_cfg();
        let t0 = Instant::now();
        let res = simulate(&t1m, &cfg);
        let wall = t0.elapsed().as_secs_f64();
        let req_per_s = t1m.len() as f64 / wall.max(1e-9);
        println!(
            "{:>16}: {wall:6.2} s wall | {req_per_s:9.0} sim req/s | {} requests | {}",
            "chunked 1M",
            t1m.len(),
            res.report.summary_line()
        );
        points.push(Json::obj(vec![
            ("label", Json::Str("chunked 1M".into())),
            ("requests", Json::Num(t1m.len() as f64)),
            ("wall_s", Json::Num(wall)),
            ("sim_req_per_s", Json::Num(req_per_s)),
        ]));
    }

    // Flight-recorder overhead (DESIGN.md §3.10). The runs above pay the
    // disabled recorder — a single `Option` check per executor callback —
    // so their `sim_req_per_s` is the cross-commit ≤3% no-op guard (the
    // CI artifact diff). Here the same chunked config runs once more with
    // the flight recorder attached: the recorder must be a pure observer
    // (byte-identical report), and its full cost lands in the artifact.
    let cfg = bench_cfg();
    let opts = TelemetryOpts::new(cfg.serving.slo);
    let t0 = Instant::now();
    let traced = simulate_traced(&trace, &cfg, Some(opts));
    let wall_flight = t0.elapsed().as_secs_f64();
    assert_eq!(
        base_report,
        traced.report.to_json().to_string(),
        "flight recorder perturbed the simulation"
    );
    let tel = traced.telemetry.expect("telemetry requested");
    let overhead = wall_flight / base_wall.max(1e-9) - 1.0;
    println!(
        "{:>16}: {wall_flight:6.2} s wall | {:+5.1}% vs disabled | \
         {} samples, {} attribution rows",
        "flight recorder",
        overhead * 100.0,
        tel.timeline.as_arr().map(|a| a.len()).unwrap_or(0),
        tel.audit.attribution_rows,
    );
    points.push(Json::obj(vec![
        ("label", Json::Str("flight recorder".into())),
        ("wall_s", Json::Num(wall_flight)),
        (
            "sim_req_per_s",
            Json::Num(trace.len() as f64 / wall_flight.max(1e-9)),
        ),
        ("flight_overhead_frac", Json::Num(overhead)),
    ]));

    if let Some(path) = args.opt_str("json-out") {
        let out = Json::obj(vec![
            ("bench", Json::Str("sim_throughput".into())),
            ("requests", Json::Num(trace.len() as f64)),
            ("points", Json::Arr(points)),
        ]);
        std::fs::write(path, out.to_pretty()).expect("write json");
        println!("wrote {path}");
    }
}
