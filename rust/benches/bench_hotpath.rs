//! L3 hot-path microbenchmarks (§Perf): the per-iteration scheduler cost.
//!
//! Every strict-instance decode iteration runs Algorithm 2; at a 10-100 ms
//! TPOT budget the scheduler must cost microseconds, not milliseconds.
//! Measures: O(1) latency predictor, full mix-decode selection across pool
//! sizes, KV allocator churn, and end-to-end simulated steps/second.

use std::time::Instant;

use ooco::config::{HardwareProfile, ModelSpec, ServingConfig};
use ooco::scheduler::{select_decode_batch, Candidate, Policy};
use ooco::kvcache::KvManager;
use ooco::perfmodel::{BatchStats, PerfModel};
use ooco::sim::{simulate, SimConfig};
use ooco::trace::datasets::DatasetProfile;
use ooco::trace::generator::{offline_trace, online_trace};
use ooco::util::rng::Pcg;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<52} {:>12.3} us/op", per * 1e6);
    per
}

fn main() {
    println!("=== L3 hot-path microbenchmarks ===");
    let pm = PerfModel::new(ModelSpec::qwen2_5_7b(), HardwareProfile::ascend_910c());

    // 1. O(1) decode-latency predictor.
    let mut acc = 0.0f64;
    bench("decode_latency predictor (O(1))", 2_000_000, || {
        acc += pm.decode_latency(BatchStats::new(128, 128_000));
    });
    std::hint::black_box(acc);

    // 2. Mix-decode selection across offline pool sizes.
    for &m in &[16usize, 64, 256, 1024] {
        let online: Vec<Candidate> = (0..16).map(|i| (i as u64, 1000)).collect();
        let offline: Vec<Candidate> = (0..m)
            .map(|i| (100 + i as u64, 200 + (i * 37) % 2000))
            .collect();
        let mut rng = Pcg::seeded(3);
        bench(
            &format!("mix_decode selection (online=16, offline={m})"),
            20_000,
            || {
                let sel =
                    select_decode_batch(&pm, &online, &offline, 0.08, 8, &mut rng);
                std::hint::black_box(sel.stats);
            },
        );
    }

    // 3. KV allocator churn (admit/grow/release cycle).
    let mut kv = KvManager::new(1_000_000, 16);
    let mut id = 0u64;
    let mut live: Vec<u64> = Vec::new();
    let mut rng = Pcg::seeded(5);
    bench("kv allocator admit+grow+release mix", 200_000, || {
        match rng.below(4) {
            0 => {
                if kv.admit(id, rng.below(2000) + 1).is_ok() {
                    live.push(id);
                }
                id += 1;
            }
            3 if !live.is_empty() => {
                let i = rng.below(live.len());
                let v = live.swap_remove(i);
                let _ = kv.release(v);
            }
            _ if !live.is_empty() => {
                let v = live[rng.below(live.len())];
                let _ = kv.grow(v, 1);
            }
            _ => {}
        }
    });

    // 4. End-to-end simulator throughput (events/s) — the macro number.
    println!("\n=== simulator macro throughput ===");
    let online = online_trace(DatasetProfile::azure_conv(), 0.5, 900.0, 42);
    let offline = offline_trace(DatasetProfile::ooc_offline(), 10.0, 900.0, 43);
    let trace = online.merge(offline);
    let cfg = SimConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
    let t0 = Instant::now();
    let res = simulate(&trace, &cfg);
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "sim 900s trace ({} reqs): {:.2}s wall, {:.0} strict steps/s-wall, {:.0}x realtime",
        trace.len(),
        wall,
        res.strict_steps as f64 / wall,
        900.0 / wall
    );
}
