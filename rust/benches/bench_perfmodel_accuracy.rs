//! Performance-model accuracy (§3.3.2): the paper validates its roofline
//! model at ~5% mean absolute error against real execution on the 910c.
//! We replicate the methodology on our testbed: measure real PJRT
//! latencies of the tiny model across prefill/decode shapes, fit the
//! achievable-rate parameters from half the samples (the paper's "small
//! amount of profiling data"), and report the error on the held-out half.
//!
//! Requires `make artifacts`; skips gracefully otherwise.

use std::time::Instant;

use ooco::config::HardwareProfile;
use ooco::perfmodel::{
    calibrate, mean_abs_rel_error, BatchStats, PerfModel, Sample, SampleKind,
};
use ooco::runtime::{DecodeEntry, KvBuf, Runtime};
use ooco::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("bench_perfmodel_accuracy: artifacts not built, skipping");
        return Ok(());
    }
    println!("=== Perf-model accuracy (paper §3.3.2: ~5% on the 910c) ===");
    println!("loading runtime...");
    let rt = Runtime::load(dir)?;
    let mut rng = Pcg::seeded(11);

    // Measure a grid of real executions (median of 3 runs each).
    let mut samples: Vec<Sample> = Vec::new();
    for &s in &rt.manifest.prefill_buckets.clone() {
        for frac in [0.5, 0.95] {
            let len = ((s as f64 * frac) as usize).max(1);
            let toks: Vec<i32> = (0..len)
                .map(|_| rng.below(rt.manifest.vocab) as i32)
                .collect();
            let lat = median3(|| {
                let t0 = Instant::now();
                rt.prefill(&toks).unwrap();
                t0.elapsed().as_secs_f64()
            });
            samples.push(Sample {
                kind: SampleKind::Prefill { prompt_len: len },
                latency_s: lat,
            });
        }
    }
    let kv_elems = rt.kv_elems();
    for &b in &rt.manifest.decode_buckets.clone() {
        for kv_len in [32usize, 256] {
            let mut kvs: Vec<KvBuf> =
                (0..b).map(|_| KvBuf::zeros(kv_elems)).collect();
            let lat = median3(|| {
                let mut entries: Vec<DecodeEntry> = kvs
                    .iter_mut()
                    .map(|kv| DecodeEntry {
                        token: 1,
                        position: kv_len as i32,
                        kv,
                    })
                    .collect();
                let t0 = Instant::now();
                rt.decode(&mut entries).unwrap();
                t0.elapsed().as_secs_f64()
            });
            samples.push(Sample {
                kind: SampleKind::Decode {
                    batch: BatchStats::new(b, b * kv_len),
                },
                latency_s: lat,
            });
        }
    }

    // Split into calibration / held-out halves.
    let (cal, held): (Vec<_>, Vec<_>) = samples
        .iter()
        .enumerate()
        .partition(|(i, _)| i % 2 == 0);
    let cal: Vec<Sample> = cal.into_iter().map(|(_, s)| *s).collect();
    let held: Vec<Sample> = held.into_iter().map(|(_, s)| *s).collect();

    let model = {
        let m = &rt.manifest;
        ooco::config::ModelSpec {
            name: "tiny".into(),
            layers: m.layers,
            hidden: m.hidden,
            q_heads: m.q_heads,
            kv_heads: m.kv_heads,
            head_dim: m.head_dim,
            ffn: m.ffn,
            vocab: m.vocab,
            bytes_per_value: 4.0,
            tensor_parallel: 1,
        }
    };
    let initial = HardwareProfile::cpu_tiny();
    let before = mean_abs_rel_error(&model, &initial, &held);
    let fitted = calibrate(&model, &initial, &cal, 14);
    let after_cal = mean_abs_rel_error(&model, &fitted, &cal);
    let after_held = mean_abs_rel_error(&model, &fitted, &held);

    println!("\nsamples: {} measured ({} cal / {} held out)", samples.len(), cal.len(), held.len());
    println!("mean abs rel error, uncalibrated profile: {:.1}%", before * 100.0);
    println!("mean abs rel error, calibration set:      {:.1}%", after_cal * 100.0);
    println!("mean abs rel error, held-out set:         {:.1}%", after_held * 100.0);
    println!("(paper reports ~5% on Qwen2.5 7B/72B @ 910c; CPU timing jitter");
    println!(" on interpret-mode kernels makes our bound looser)");

    let pm = PerfModel::new(model, fitted.clone());
    println!("\nfitted profile: F_g {:.2} GFLOP/s, M_g {:.2} GB/s, O_p {:.2} ms, O_d {:.2} ms",
        fitted.flops_gemm / 1e9, fitted.bw_gemm / 1e9,
        fitted.overhead_prefill * 1e3, fitted.overhead_decode * 1e3);
    println!("\n-- per-sample detail (held out) --");
    println!("{:<32} {:>12} {:>12} {:>8}", "shape", "measured", "predicted", "err%");
    for s in &held {
        let pred = match s.kind {
            SampleKind::Prefill { prompt_len } => pm.prefill_latency(prompt_len),
            SampleKind::Decode { batch } => pm.decode_latency(batch),
        };
        let name = match s.kind {
            SampleKind::Prefill { prompt_len } => format!("prefill s={prompt_len}"),
            SampleKind::Decode { batch } => {
                format!("decode B={} kv={}", batch.size, batch.total_kv_tokens)
            }
        };
        println!(
            "{:<32} {:>10.2}ms {:>10.2}ms {:>7.1}%",
            name,
            s.latency_s * 1e3,
            pred * 1e3,
            ((pred - s.latency_s) / s.latency_s * 100.0).abs()
        );
    }
    Ok(())
}

fn median3<F: FnMut() -> f64>(mut f: F) -> f64 {
    let mut v = [f(), f(), f()];
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[1]
}
