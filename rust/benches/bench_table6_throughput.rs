//! Table 6 reproduction: maximum throughput of Qwen2.5-7B across
//! frameworks/hardware — vLLM @ H800, vLLM @ 910c (single chip), xLLM @
//! 910c (single chip) — on the Azure Conv request mix, non-disaggregated,
//! pushed to saturation.
//!
//! Substrate substitution (DESIGN.md §2): the three platforms are
//! perf-model hardware profiles; saturation throughput comes from the
//! steady-state continuous-batching model
//!
//!   lambda = 1 / (T_prefill(p) + o * L_decode(B, kv) / B),
//!   tokens/s = lambda * (p + o),  maximized over the batch size B
//!
//! which matches the paper's observation that the ratio tracks theoretical
//! peak FLOP/s. Absolute numbers are expected to land in the same range as
//! Table 6 because the 910c/A100 and H800 profiles encode real ratings.

use ooco::config::{HardwareProfile, ModelSpec};
use ooco::perfmodel::{BatchStats, PerfModel};
use ooco::util::cli::Args;

/// Max sustained total token throughput (prompt+output tokens/s) for a
/// non-disaggregated instance on the given profile.
fn saturation_throughput(pm: &PerfModel, prompt: f64, output: f64) -> (f64, usize) {
    let cap = pm.max_kv_tokens();
    let mean_kv = prompt + output / 2.0;
    let mut best = 0.0f64;
    let mut best_b = 1usize;
    let t_p = pm.prefill_latency(prompt as usize);
    let mut b = 1usize;
    while (b as f64) * mean_kv <= cap as f64 {
        let l = pm.decode_latency(BatchStats::new(b, (b as f64 * mean_kv) as usize));
        let per_req = t_p + output * l / b as f64;
        let thr = (prompt + output) / per_req;
        if thr > best {
            best = thr;
            best_b = b;
        }
        b = (b as f64 * 1.3).ceil() as usize;
    }
    (best, best_b)
}

fn main() {
    let args = Args::parse_env();
    let model = ModelSpec::qwen2_5_7b();
    // Azure Conv request mix (Table 5).
    let prompt = args.f64("prompt", 1512.30);
    let output = args.f64("output", 98.75);

    println!("=== Table 6: max throughput, Qwen2.5-7B, Azure Conv mix ===");
    println!(
        "{:<34} {:>16} {:>16} {:>10}",
        "framework / hardware", "paper tok/s", "ours tok/s", "best B"
    );

    let rows: Vec<(&str, HardwareProfile, f64)> = vec![
        ("vLLM @ NVIDIA H800", HardwareProfile::h800(), 36099.72),
        (
            "vLLM @ Ascend 910c (single chip)",
            HardwareProfile::ascend_910c_vllm(),
            10050.44,
        ),
        (
            "xLLM @ Ascend 910c (single chip)",
            HardwareProfile::ascend_910c(),
            12083.43,
        ),
    ];

    let mut ours = Vec::new();
    for (name, hw, paper) in &rows {
        let pm = PerfModel::new(model.clone(), hw.clone());
        let (thr, b) = saturation_throughput(&pm, prompt, output);
        ours.push(thr);
        println!("{:<34} {:>16.2} {:>16.2} {:>10}", name, paper, thr, b);
    }

    println!("\n-- ratio structure (the paper's claim) --");
    println!(
        "H800 / vLLM-910c:  paper {:.2}x, ours {:.2}x (theoretical peak ratio 3.0x)",
        36099.72 / 10050.44,
        ours[0] / ours[1]
    );
    println!(
        "xLLM / vLLM @910c: paper {:.2}x, ours {:.2}x",
        12083.43 / 10050.44,
        ours[2] / ours[1]
    );
}
