//! Fleet failover bench (DESIGN.md §3.9): the same co-locate trace under
//! the same relaxed-instance crash schedule, recovered two ways —
//!
//!   restream  — crashes arrive with advance notice; resident offline KV
//!               evacuates through the recoverable-eviction transport
//!               paths (host staging / live relaxed instances) and
//!               restreams after the crash instead of being recomputed;
//!   recompute — identical schedule with the notice stripped; whatever KV
//!               the crash catches is lost and re-prefilled from scratch.
//!
//! The headline: restream recovery spares recompute tokens and holds (or
//! beats) recompute recovery on offline throughput, while online p99 TTFT
//! inside the down windows stays within the failover SLO bound.
//!
//! Run: `cargo bench --bench bench_fleet_failover [-- --json-out BENCH_fleet_failover.json]`

use std::time::Instant;

use ooco::config::{FaultSpec, FleetSpec, ServingConfig};
use ooco::coordinator::Policy;
use ooco::sweep::{failover_compare, SweepConfig};
use ooco::trace::datasets::{DatasetProfile, LengthProfile};
use ooco::trace::generator::{offline_trace, online_trace};
use ooco::trace::Trace;
use ooco::util::cli::Args;
use ooco::util::json::Json;

/// Offline-heavy co-locate load: deep backlog and long offline contexts so
/// a relaxed-instance crash has real KV at stake.
fn failover_trace() -> Trace {
    let duration = 900.0;
    let mut online_ds = DatasetProfile::azure_conv();
    online_ds.output = LengthProfile::new(60.0, 0.6, 4, 200);
    let mut offline_ds = DatasetProfile::ooc_offline();
    offline_ds.prompt = LengthProfile::new(2400.0, 0.8, 64, 8192);
    offline_ds.output = LengthProfile::new(220.0, 0.6, 16, 800);
    let online = online_trace(online_ds, 0.5, duration, 2026);
    let offline = offline_trace(offline_ds, 6.0, duration, 2027);
    online.merge(offline)
}

fn main() {
    let args = Args::parse_env();
    let trace = failover_trace();

    let mut serving = ServingConfig::preset_7b();
    serving.cluster.relaxed_instances = 2;
    serving.cluster.strict_instances = 2;
    let slo = serving.slo;

    // Three relaxed crashes spread across the run, 45 s of notice each,
    // two minutes down — enough KV at stake per crash to matter, never
    // the last live instance (the two never overlap).
    let fault: FaultSpec =
        "crash(at=200,inst=0,down=120,notice=45); \
         crash(at=420,inst=1,down=120,notice=45); \
         crash(at=640,inst=0,down=120,notice=45)"
            .parse()
            .expect("static schedule parses");
    let sweep = SweepConfig {
        duration_s: trace.duration(),
        seed: 2028,
        ..Default::default()
    };

    println!(
        "trace: {} requests, {:.0} s span | schedule: {fault}",
        trace.len(),
        trace.duration()
    );

    let t0 = Instant::now();
    let (restream, recompute) = failover_compare(
        &serving,
        Policy::Ooco,
        &trace,
        FleetSpec::default(),
        &fault,
        &sweep,
    );
    let wall = t0.elapsed().as_secs_f64();

    for (label, res) in
        [("restream", &restream), ("recompute", &recompute)]
    {
        println!("{label:>10}: {}", res.report.summary_line());
        println!("{:>10}  {}", "", res.fleet.summary_line());
    }
    println!(
        "offline throughput: restream {:.1} tok/s vs recompute {:.1} tok/s ({:+.1}%) | {wall:.1} s wall",
        restream.report.offline_token_throughput,
        recompute.report.offline_token_throughput,
        100.0
            * (restream.report.offline_token_throughput
                / recompute.report.offline_token_throughput.max(1e-9)
                - 1.0),
    );

    // The claims this bench exists to pin.
    assert_eq!(restream.fleet.crashes, 3, "all three crashes must fire");
    assert_eq!(restream.fleet.accounting_errors, 0);
    assert_eq!(recompute.fleet.accounting_errors, 0);
    assert!(
        restream.fleet.evacuated_tokens > 0,
        "advance notice must evacuate some KV"
    );
    assert!(
        restream.fleet.recompute_tokens <= recompute.fleet.recompute_tokens,
        "evacuated KV must shrink the recompute bill ({} vs {})",
        restream.fleet.recompute_tokens,
        recompute.fleet.recompute_tokens,
    );
    assert!(
        restream.report.offline_token_throughput
            >= recompute.report.offline_token_throughput,
        "restream recovery must hold or beat recompute on offline throughput ({:.1} vs {:.1} tok/s)",
        restream.report.offline_token_throughput,
        recompute.report.offline_token_throughput,
    );
    // Online latency during the down windows: p99 TTFT within the
    // failover bound (5x the steady-state SLO).
    let bound = 5.0 * slo.ttft;
    for (label, res) in
        [("restream", &restream), ("recompute", &recompute)]
    {
        assert!(
            res.fleet.failover_ttft.p99 <= bound,
            "{label}: failover p99 ttft {:.2}s exceeds {bound:.1}s",
            res.fleet.failover_ttft.p99,
        );
    }

    if let Some(path) = args.opt_str("json-out") {
        let side = |res: &ooco::fleet::FleetResult| {
            Json::obj(vec![
                ("report", res.report.to_json()),
                ("fleet", res.fleet.to_json()),
            ])
        };
        let out = Json::obj(vec![
            ("bench", Json::Str("fleet_failover".into())),
            ("schedule", fault.to_json()),
            ("restream", side(&restream)),
            ("recompute", side(&recompute)),
            (
                "throughput_gain",
                Json::Num(
                    restream.report.offline_token_throughput
                        / recompute
                            .report
                            .offline_token_throughput
                            .max(1e-9),
                ),
            ),
            ("wall_s", Json::Num(wall)),
        ]);
        std::fs::write(path, out.to_pretty()).expect("write json");
        println!("wrote {path}");
    }
}
