//! Prefix-sharing KV cache evaluation (DESIGN.md §3.7, ours): offline
//! throughput and online TTFT with the cache on vs off across sharing
//! regimes.
//!
//! Three regimes span the sharing spectrum of co-located offline work:
//! `no-share` (independent batch prompts — the cache must at least do no
//! harm), `50% shared` (one system prompt roughly the size of the mean
//! body, the HyGen-style batch-job shape), and `agentic heavy-share`
//! (multi-turn conversations whose context grows turn over turn, so each
//! turn recomputes only the last exchange). Online azure-conv traffic
//! rides along in every regime to watch for SLO regressions.
//!
//! Reports per regime and cache setting: online attainment, TTFT/TPOT p99,
//! offline token throughput, and the prefix summary; then a verdict line
//! like `bench_elastic_pools.rs`. Run:
//! `cargo bench --bench bench_prefix_cache [-- --duration 600]`

use ooco::config::ServingConfig;
use ooco::scheduler::Policy;
use ooco::sim::{simulate, SimConfig, SimResult};
use ooco::trace::datasets::DatasetProfile;
use ooco::trace::generator::{offline_trace_with_prefix, online_trace};
use ooco::trace::{PrefixProfile, Trace};
use ooco::util::cli::Args;

fn mixed_trace(
    offline_prefix: PrefixProfile,
    online_rate: f64,
    offline_qps: f64,
    duration: f64,
    seed: u64,
) -> Trace {
    let online =
        online_trace(DatasetProfile::azure_conv(), online_rate, duration, seed);
    let offline = offline_trace_with_prefix(
        DatasetProfile::ooc_offline(),
        offline_qps,
        duration,
        offline_prefix,
        seed + 1,
    );
    online.merge(offline)
}

fn run(trace: &Trace, cache_on: bool, mem_gb: f64, seed: u64) -> SimResult {
    let mut serving = ServingConfig::preset_7b();
    serving.hardware.mem_capacity = mem_gb * 1e9;
    serving.prefix.enabled = cache_on;
    let mut cfg = SimConfig::new(serving, Policy::Ooco);
    cfg.seed = seed;
    simulate(trace, &cfg)
}

fn main() {
    let args = Args::parse_env();
    let duration = args.f64("duration", 600.0);
    let online_rate = args.f64("online-rate", 0.3);
    let offline_qps = args.f64("offline-qps", 3.0);
    let mem_gb = args.f64("mem-gb", 24.0);
    let seed = args.u64("seed", 42);

    let regimes: [(&str, PrefixProfile); 3] = [
        ("no-share", PrefixProfile::None),
        (
            "50% shared",
            PrefixProfile::SharedSystem { prefix_len: 1200 },
        ),
        (
            "agentic heavy-share",
            PrefixProfile::Agentic {
                conversations: 16,
                turns: 6,
            },
        ),
    ];

    println!(
        "# prefix cache: online {online_rate} req/s + offline {offline_qps} qps over {duration}s, {mem_gb} GB/instance"
    );
    let mut wins = 0usize;
    for (name, profile) in regimes {
        let trace =
            mixed_trace(profile, online_rate, offline_qps, duration, seed);
        println!(
            "\n## {name} ({} online / {} offline requests)",
            trace.count_class(ooco::request::Class::Online),
            trace.count_class(ooco::request::Class::Offline)
        );
        let mut results: Vec<(&str, SimResult)> = Vec::new();
        for (label, on) in [("cache-off", false), ("cache-on", true)] {
            let res = run(&trace, on, mem_gb, seed);
            println!(
                "{label:>9}: attain {:6.2}% | ttft p99 {:6.3}s tpot p99 {:5.1}ms | offline {:8.1} tok/s | {}",
                (1.0 - res.report.online_violation_rate) * 100.0,
                res.report.ttft.p99,
                res.report.tpot.p99 * 1e3,
                res.report.offline_token_throughput,
                res.prefix.summary_line(),
            );
            results.push((label, res));
        }
        let off = &results[0].1;
        let on = &results[1].1;
        let off_attain = 1.0 - off.report.online_violation_rate;
        let on_attain = 1.0 - on.report.online_violation_rate;
        let off_tput = off.report.offline_token_throughput;
        let on_tput = on.report.offline_token_throughput;
        // "No SLO regression": within half a percentage point.
        if on_attain >= off_attain - 0.005 && on_tput > off_tput {
            wins += 1;
            println!(
                "=> cache wins `{name}`: offline {on_tput:.1} vs {off_tput:.1} tok/s (+{:.1}%) at hit rate {:.1}%, no SLO regression",
                (on_tput / off_tput.max(1e-9) - 1.0) * 100.0,
                on.prefix.hit_rate * 100.0,
            );
        } else {
            println!(
                "=> no win on `{name}` (cache {on_tput:.1} tok/s @ {:.2}% vs cold {off_tput:.1} @ {:.2}%)",
                on_attain * 100.0,
                off_attain * 100.0,
            );
        }
    }
    println!("\n{wins} of {} regimes won by the prefix cache", 3);
}
