//! Elastic pool manager evaluation (DESIGN.md §3.6, ours): static
//! strict/relaxed split vs `Periodic` vs `Reactive` repartitioning on a
//! diurnal tide + burst trace.
//!
//! The workload compresses one tide edge into the run: a peak phase at
//! `--peak` base rate (azure-conv bursts ride along) followed by a trough
//! phase at `--trough`, with a saturating offline backlog throughout. A
//! static split must provision the strict pool for the peak and strands
//! that capacity through the trough; the elastic policies hand it to the
//! relaxed pool once the estimator sees the tide fall — more offline
//! throughput at equal online SLO attainment. Memory is squeezed
//! (`--mem-gb`, default 20) so per-instance KV capacity binds at
//! bench-scale load, exactly like `bench_fast_preemption`.
//!
//! Reports, per offline-QPS regime and pool policy: online violation rate,
//! TTFT/TPOT p99, offline token throughput, flips, transition p50, and
//! stranded capacity; then a verdict line per regime. Run:
//! `cargo bench --bench bench_elastic_pools [-- --duration 900]`

use ooco::config::{PoolPolicy, ServingConfig};
use ooco::scheduler::Policy;
use ooco::sim::{simulate, SimConfig, SimResult};
use ooco::trace::datasets::DatasetProfile;
use ooco::trace::generator::two_phase_trace;
use ooco::trace::Trace;
use ooco::util::cli::Args;

fn tide_trace(
    peak_base: f64,
    trough_base: f64,
    duration: f64,
    offline_qps: f64,
    seed: u64,
) -> Trace {
    two_phase_trace(
        DatasetProfile::azure_conv(),
        peak_base,
        trough_base,
        duration / 2.0,
        DatasetProfile::ooc_offline(),
        offline_qps,
        seed,
    )
}

fn run(
    trace: &Trace,
    pool: PoolPolicy,
    mem_gb: f64,
    seed: u64,
) -> SimResult {
    let mut serving = ServingConfig::preset_7b();
    serving.hardware.mem_capacity = mem_gb * 1e9;
    // Static peak provisioning: half the cluster each.
    serving.cluster.relaxed_instances = 2;
    serving.cluster.strict_instances = 2;
    serving.pool = pool;
    let mut cfg = SimConfig::new(serving, Policy::Ooco);
    cfg.seed = seed;
    simulate(trace, &cfg)
}

fn main() {
    let args = Args::parse_env();
    let duration = args.f64("duration", 900.0);
    // Base rates; azure-conv's tide starts at the mid-morning ramp, so the
    // effective peak is ≈ 1.4× the base — ~7 req/s needs two strict
    // instances at the squeezed memory, the trough needs one.
    let peak = args.f64("peak", 5.0);
    let trough = args.f64("trough", 0.5);
    let mem_gb = args.f64("mem-gb", 20.0);
    let qps_levels = args.f64_list("qps", &[4.0, 10.0]);
    let seed = args.u64("seed", 42);

    let policies: [(&str, PoolPolicy); 3] = [
        ("static", PoolPolicy::Static),
        (
            "periodic",
            PoolPolicy::Periodic {
                epoch_s: 30.0,
                headroom: 0.15,
            },
        ),
        ("reactive", PoolPolicy::DEFAULT_REACTIVE),
    ];

    println!(
        "# elastic pools: tide {peak}->{trough} base req/s over {duration}s, \
         2r/2s x {mem_gb} GB, offline qps {qps_levels:?}"
    );
    let mut wins = 0usize;
    for &qps in &qps_levels {
        let trace = tide_trace(peak, trough, duration, qps, seed);
        println!(
            "\n## offline {qps} qps ({} online / {} offline requests)",
            trace.count_class(ooco::request::Class::Online),
            trace.count_class(ooco::request::Class::Offline)
        );
        let mut stat_attain = 0.0;
        let mut stat_tput = 0.0;
        let mut elastic: Vec<(&str, f64, f64)> = Vec::new();
        for (name, pool) in policies {
            let res = run(&trace, pool, mem_gb, seed);
            let attain = 1.0 - res.report.online_violation_rate;
            println!(
                "{name:>9}: attain {:6.2}% | ttft p99 {:6.3}s tpot p99 {:5.1}ms | offline {:8.1} tok/s | {}",
                attain * 100.0,
                res.report.ttft.p99,
                res.report.tpot.p99 * 1e3,
                res.report.offline_token_throughput,
                res.pool.summary_line(),
            );
            if name == "static" {
                stat_attain = attain;
                stat_tput = res.report.offline_token_throughput;
            } else {
                elastic.push((
                    name,
                    attain,
                    res.report.offline_token_throughput,
                ));
            }
        }
        // "Equal online SLO attainment": within half a percentage point of
        // the static split (both typically sit at ~100%). Filter first,
        // then take the best-throughput qualifier — a high-throughput
        // policy that trades away SLO must not mask a qualified winner.
        let winner = elastic
            .iter()
            .filter(|(_, attain, _)| *attain >= stat_attain - 0.005)
            .max_by(|a, b| a.2.total_cmp(&b.2))
            .copied();
        match winner {
            Some((name, _, tput)) if tput > stat_tput => {
                wins += 1;
                println!(
                    "=> regime won by `{name}`: offline {:.1} vs static {:.1} tok/s (+{:.1}%) at equal SLO attainment",
                    tput,
                    stat_tput,
                    (tput / stat_tput.max(1e-9) - 1.0) * 100.0
                );
            }
            _ => {
                let (name, attain, tput) = elastic
                    .iter()
                    .max_by(|a, b| a.2.total_cmp(&b.2))
                    .copied()
                    .expect("two elastic policies ran");
                println!(
                    "=> static holds this regime (best elastic `{name}` {:.1} tok/s @ {:.2}% vs static {:.1} @ {:.2}%)",
                    tput,
                    attain * 100.0,
                    stat_tput,
                    stat_attain * 100.0
                );
            }
        }
    }
    println!(
        "\n{} of {} regimes won by elastic repartitioning",
        wins,
        qps_levels.len()
    );
}
