//! Ablation study (ours): start from full OOCO and disable one mechanism
//! at a time — mix-decode selection (Algorithm 2), migration (Algorithm 1),
//! offline gating, bottleneck-aware eviction — measuring max effective
//! offline throughput and online SLO health at a saturating offline load.

use ooco::config::ServingConfig;
use ooco::coordinator::{Ablation, Policy};
use ooco::sweep::{max_effective_offline, offline_sweep, qps_grid, SweepConfig};
use ooco::trace::datasets::DatasetProfile;
use ooco::util::cli::Args;

fn main() {
    let args = Args::parse_env();
    let duration = args.f64("duration", 1200.0);
    let online_rate = args.f64("online-rate", 0.5);
    let seed = args.u64("seed", 42);

    let serving = ServingConfig::preset_7b();
    let online_ds = DatasetProfile::azure_conv();
    let offline_ds = DatasetProfile::ooc_offline();
    let grid = qps_grid(1.0, 40.0, 6);

    println!("=== Ablation: OOCO mechanisms (7B, Azure Conv online) ===");
    println!(
        "{:<28} {:>16} {:>10} {:>10} {:>10}",
        "variant", "max eff tok/s", "vs full", "mig@max", "evic@max"
    );

    let variants: Vec<(&str, Ablation)> = vec![
        ("full OOCO", Ablation::full()),
        ("- mix-decode (Alg. 2)", Ablation::without_mix_decode()),
        ("- migration (Alg. 1)", Ablation::without_migration()),
        ("- gating cost model", Ablation::without_gating()),
        ("- bottleneck eviction", Ablation::without_bottleneck_eviction()),
    ];

    let mut full_eff = None;
    for (name, ablation) in variants {
        let sweep = SweepConfig {
            duration_s: duration,
            seed,
            ablation,
            ..Default::default()
        };
        let pts = offline_sweep(
            &serving,
            Policy::Ooco,
            &online_ds,
            online_rate,
            &offline_ds,
            &grid,
            &sweep,
        );
        let eff = max_effective_offline(&pts, serving.slo.violation_threshold);
        let last_ok = pts
            .iter()
            .rev()
            .find(|p| p.violation_rate <= serving.slo.violation_threshold);
        let (mig, evic) = last_ok.map(|p| (p.migrations, p.evictions)).unwrap_or((0, 0));
        let rel = match full_eff {
            None => {
                full_eff = Some(eff);
                1.0
            }
            Some(f) => eff / f,
        };
        println!(
            "{:<28} {:>16.1} {:>9.2}x {:>10} {:>10}",
            name, eff, rel, mig, evic
        );
    }
    println!("\n(variants at 1.00x indicate the mechanism matters under other");
    println!(" workload mixes — e.g. bottleneck eviction needs memory pressure)");
}
