//! Fast-preemption evaluation (ours, extending §3.4.1): recoverable
//! eviction — evicted offline decodes stream their KV to the relaxed pool
//! or host staging and resume without recompute — against classic
//! discard-and-recompute, across interconnect bottleneck regimes.
//!
//! Reports, per pool-link bandwidth: offline token throughput, online TTFT
//! (does the online class stay whole while evictions churn), recompute
//! evictions vs rescues/offloads, and the preemption-to-restart latency
//! distribution (the "preemption delay" the request actually experiences).
//!
//! Run: `cargo bench --bench bench_fast_preemption [-- --duration 600]`

use ooco::config::ServingConfig;
use ooco::scheduler::Policy;
use ooco::sim::{simulate, SimConfig};
use ooco::trace::datasets::DatasetProfile;
use ooco::trace::generator::{offline_trace, online_trace};
use ooco::util::cli::Args;

fn main() {
    let args = Args::parse_env();
    let duration = args.f64("duration", 600.0);
    let online_rate = args.f64("online-rate", 0.8);
    let offline_qps = args.f64("offline-qps", 4.0);
    // Shrunk device memory keeps both pools under constant KV pressure so
    // eviction (the mechanism under test) actually churns.
    let mem_gb = args.f64("mem-gb", 18.0);
    let seed = args.u64("seed", 42);

    let online = online_trace(
        DatasetProfile::azure_conv(),
        online_rate,
        duration,
        seed,
    );
    let offline = offline_trace(
        DatasetProfile::ooc_offline(),
        offline_qps,
        duration,
        seed + 1,
    );
    let trace = online.merge(offline);

    println!(
        "=== Fast preemption: recoverable eviction vs discard-and-recompute ==="
    );
    println!(
        "(7B, mem {mem_gb:.0} GB/chip, online {online_rate} qps + offline {offline_qps} qps, {duration:.0}s trace)"
    );
    println!();
    println!(
        "{:<9} {:<10} {:>10} {:>9} {:>9} {:>8} {:>8} {:>9} {:>12} {:>12}",
        "pool BW",
        "eviction",
        "off tok/s",
        "ttft p50",
        "ttft p99",
        "recomp",
        "rescues",
        "offloads",
        "restart p50",
        "restart p99"
    );

    // Bottleneck regimes: RDMA-class, constrained, and starved interconnect.
    for bw_gbs in [25.0, 2.0, 0.5] {
        let mut discard_tput = 0.0;
        for recover in [false, true] {
            let mut serving = ServingConfig::preset_7b();
            serving.hardware.mem_capacity = mem_gb * 1e9;
            serving.transport.pool.bandwidth = bw_gbs * 1e9;
            serving.transport.recoverable_eviction = recover;
            serving.transport.host_staging = recover;
            let mut cfg = SimConfig::new(serving, Policy::Ooco);
            cfg.drain_s = 3000.0;
            cfg.seed = seed;
            let res = simulate(&trace, &cfg);
            let rl = &res.transport.restart_latency;
            println!(
                "{:<9} {:<10} {:>10.1} {:>8.2}s {:>8.2}s {:>8} {:>8} {:>9} {:>11.3}s {:>11.3}s",
                format!("{bw_gbs} GB/s"),
                if recover { "recover" } else { "discard" },
                res.report.offline_token_throughput,
                res.report.ttft.p50,
                res.report.ttft.p99,
                res.evictions,
                res.rescues,
                res.offloads,
                rl.p50,
                rl.p99,
            );
            if recover {
                if discard_tput > 0.0 {
                    println!(
                        "{:<9} {:<10} {:>9.2}x offline-throughput vs discard | transfer stall {:.1}s | {}",
                        "",
                        "",
                        res.report.offline_token_throughput / discard_tput,
                        res.transport.stall_s,
                        res.transport.summary_line(),
                    );
                }
            } else {
                discard_tput = res.report.offline_token_throughput;
            }
        }
        println!();
    }
    println!("(recoverable eviction turns recompute churn into cheap KV");
    println!(" streams; the gap widens as the interconnect bottlenecks, until");
    println!(" the link itself becomes the preemption-delay floor)");
}
