//! Figure 3 (and the quantitative content of Figure 2) reproduction:
//! roofline analysis with corresponding latency of LLM inference —
//! Qwen2.5-7B on the 910c-like profile. Each point is one Prefill or
//! Decode execution at a given batch size / request length: arithmetic
//! intensity (FLOP/B), achieved FLOP/s, and predicted latency.

use ooco::config::{HardwareProfile, ModelSpec};
use ooco::perfmodel::{operators, BatchStats, PerfModel};
use ooco::util::cli::Args;

fn main() {
    let args = Args::parse_env();
    let model = args.str("model", "7b").parse::<ModelSpec>().unwrap();
    let hw = args.str("hw", "910c").parse::<HardwareProfile>().unwrap();
    let pm = PerfModel::new(model.clone(), hw.clone());

    println!("=== Figure 2: operator compute patterns (per layer) ===");
    println!(
        "{:<28} {:>14} {:>12} {:>10}",
        "operator", "GFLOPs", "MB moved", "FLOP/B"
    );
    for (name, cost) in [
        ("prefill GEMMs (s=2048)", operators::layer_gemms(&model, 2048.0)),
        ("prefill attention (s=2048)", operators::attention(&model, 2048.0, 2048.0)),
        ("decode GEMMs (B=128)", operators::layer_gemms(&model, 128.0)),
        ("decode attention (B=128, s=2048)", {
            let mut c = operators::attention(&model, 1.0, 2048.0);
            c = c.scale(128.0);
            c
        }),
    ] {
        println!(
            "{:<28} {:>14.2} {:>12.1} {:>10.1}",
            name,
            cost.flops / 1e9,
            cost.bytes / 1e6,
            cost.intensity()
        );
    }

    println!(
        "\n=== Figure 3: roofline + latency ({} on {}) ===",
        model.name, hw.name
    );
    println!(
        "peak(GEMM) {:.0} TFLOP/s, achievable bw {:.2} TB/s, ridge at {:.0} FLOP/B",
        hw.flops_gemm / 1e12,
        hw.bw_gemm / 1e12,
        hw.flops_gemm / hw.bw_gemm
    );

    println!("\n-- Prefill executions (one request, varying length) --");
    println!(
        "{:>8} {:>12} {:>14} {:>12} {:>10}",
        "seqlen", "FLOP/B", "TFLOP/s", "latency", "bound"
    );
    for s in [16usize, 32, 64, 128, 250, 512, 1024, 2048, 4096] {
        let c = pm.prefill_cost(&[s]);
        let bound = if c.gemm.flops / pm.hw.flops_gemm
            > c.gemm.bytes / pm.hw.bw_gemm
        {
            "compute"
        } else {
            "memory"
        };
        println!(
            "{:>8} {:>12.1} {:>14.1} {:>10.2}ms {:>10}",
            s,
            c.intensity(),
            c.achieved_flops() / 1e12,
            c.latency_s * 1e3,
            bound
        );
    }

    println!("\n-- Decode executions (varying batch, kv len) --");
    println!(
        "{:>8} {:>8} {:>12} {:>14} {:>12} {:>10}",
        "batch", "kvlen", "FLOP/B", "TFLOP/s", "latency", "bound"
    );
    for &(b, kv) in &[
        (1usize, 256usize),
        (1, 2048),
        (8, 512),
        (32, 1024),
        (64, 2048),
        (128, 512),
        (128, 2048),
        (256, 1024),
        (300, 2048),
        (512, 1024),
        (512, 2048),
    ] {
        let stats = BatchStats::new(b, b * kv);
        let c = pm.decode_cost(stats);
        println!(
            "{:>8} {:>8} {:>12.1} {:>14.1} {:>10.2}ms {:>10?}",
            b,
            kv,
            c.intensity(),
            c.achieved_flops() / 1e12,
            c.latency_s * 1e3,
            pm.decode_bottleneck(stats)
        );
    }

    println!(
        "\nbs_sat (compute-saturated decode batch) = {} \
         (paper observes saturation around ~300 on the 910c)",
        pm.bs_sat()
    );
    println!(
        "prefill compute-saturates around ~250 tokens: L({}) vs L({}) bound flip above",
        128, 250
    );
}
