//! Parallel sweep-driver bench (DESIGN.md §3.13): the same offline-load
//! sweep run sequentially and with `--jobs 4`, asserting (1) the merged
//! curves are byte-identical — worker scheduling must never leak into
//! results — and (2) the fan-out actually pays: >2x wall-clock speedup
//! whenever the host exposes at least 4 cores (skipped otherwise, so the
//! bench stays meaningful on small CI runners).
//!
//! Run: `cargo bench --bench bench_sweep_parallel` (plain binary, no
//! harness).

use std::time::Instant;

use ooco::config::ServingConfig;
use ooco::coordinator::{Ablation, Policy};
use ooco::sweep::{curve_to_json, offline_sweep_parallel, SweepConfig};
use ooco::trace::datasets::DatasetProfile;
use ooco::trace::PrefixProfile;
use ooco::util::cli::Args;
use ooco::util::json::Json;

fn main() {
    let args = Args::parse_env();
    let serving = ServingConfig::preset_7b();
    let sweep = SweepConfig {
        duration_s: args.f64("duration", 480.0),
        seed: 42,
        ablation: Ablation::full(),
        offline_prefix: PrefixProfile::None,
    };
    // Descending load levels: the expensive points start first, so the
    // atomic-cursor workers pack the makespan tightly.
    let levels = [8.0, 6.0, 4.0, 3.0, 2.0, 1.0, 0.5, 0.0];
    let run = |jobs: usize| {
        let t0 = Instant::now();
        let pts = offline_sweep_parallel(
            &serving,
            Policy::Ooco,
            &DatasetProfile::azure_conv(),
            0.4,
            &DatasetProfile::ooc_offline(),
            &levels,
            &sweep,
            jobs,
        );
        (t0.elapsed().as_secs_f64(), pts)
    };

    let (wall_seq, seq) = run(1);
    let (wall_par, par) = run(4);
    let seq_json = curve_to_json("sweep", &seq);
    let par_json = curve_to_json("sweep", &par);
    assert_eq!(
        seq_json.to_string(),
        par_json.to_string(),
        "--jobs 4 curve diverged from --jobs 1"
    );

    let speedup = wall_seq / wall_par.max(1e-9);
    println!(
        "{} levels x {:.0} s sweep | sequential {wall_seq:6.2} s | 4 jobs {wall_par:6.2} s | speedup {speedup:.2}x",
        levels.len(),
        sweep.duration_s,
    );
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cores >= 4 {
        assert!(
            speedup > 2.0,
            "expected >2x speedup at --jobs 4 on {cores} cores, got {speedup:.2}x"
        );
    } else {
        println!("only {cores} cores visible; speedup assert skipped");
    }

    if let Some(path) = args.opt_str("json-out") {
        let out = Json::obj(vec![
            ("bench", Json::Str("sweep_parallel".into())),
            ("levels", Json::Num(levels.len() as f64)),
            ("cores", Json::Num(cores as f64)),
            ("wall_seq_s", Json::Num(wall_seq)),
            ("wall_par_s", Json::Num(wall_par)),
            ("speedup", Json::Num(speedup)),
            ("curve", par_json),
        ]);
        std::fs::write(path, out.to_pretty()).expect("write json");
        println!("wrote {path}");
    }
}
