//! Fleet layer: N replica groups under one top-level router, with fault
//! injection and cross-replica work stealing (DESIGN.md §3.9).
//!
//! Each replica is a full [`SchedulerCore`] cluster — the same §3.4
//! decision loop the single-cluster simulator and the real engine run.
//! The fleet owns a discrete-event time queue (the shared
//! [`crate::scheduler::TimeQueue`] — calendar by default, heap on
//! request) whose events carry a replica tag;
//! replica-local events (arrivals, step ends, transfer chunks) replay the
//! [`crate::scheduler::VirtualExecutor`] semantics verbatim, and three
//! fleet-only kinds inject the fault model: `CrashNotice` (spot-instance
//! style advance warning → KV evacuation through the recoverable-eviction
//! transport paths), `Crash` (KV and in-flight step lost; online residents
//! re-route for full recompute, offline residents return to the backlog),
//! and `Recover` (the instance rejoins its pool empty).
//!
//! With one replica and no faults the fleet is *bit-identical* to the
//! single-cluster path: arrivals get the same event ties, the router
//! short-circuits to replica 0, stealing never engages, and the emitted
//! action stream matches `VirtualExecutor`'s — asserted by
//! `tests/fleet_properties.rs` the same way the scheduler differential
//! tests pin the executor pair.

use crate::config::{CrashEvent, FaultPool, FaultSpec, FleetSpec, RoutePolicy};
use crate::metrics::{FleetReport, Recorder, Report};
use crate::obs::{self, EventClass, ProfileReport, Subsystem};
use crate::request::{Class, RequestId};
use crate::scheduler::{
    Action, InstanceRef, JobId, QueueKind, SchedulerCore, TimeQueue,
};
use crate::sim::SimConfig;
use crate::telemetry::{TelemetryOpts, TelemetryOut, TraceRecorder};
use crate::trace::Trace;
use crate::util::json::Json;
use crate::util::rng::Pcg;
use crate::util::stats::LatencySummary;

/// Dedicated RNG stream base for stochastic fault schedules — disjoint
/// from the core's decision stream (9090) so fault sampling never
/// perturbs scheduling randomness.
const FAULT_STREAM: u64 = 0xF1EE7;
/// Dedicated RNG stream for the power-of-two-choices router.
const ROUTE_STREAM: u64 = 0xF1EE8;
/// Offline requests queue cheaply (latency-tolerant), so they count less
/// toward a replica's outstanding-load score than online requests.
const OFFLINE_LOAD_WEIGHT: f64 = 0.2;
/// Stochastic crashes pre-generated per instance — a safety cap, far above
/// what any plausible MTBF produces over a trace horizon.
const MAX_FAULTS_PER_INSTANCE: usize = 256;

/// Fleet simulation parameters: the per-replica simulator config plus the
/// fleet topology and the fault schedule.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub sim: SimConfig,
    pub fleet: FleetSpec,
    pub fault: FaultSpec,
}

impl FleetConfig {
    pub fn new(sim: SimConfig) -> Self {
        FleetConfig {
            sim,
            fleet: FleetSpec::default(),
            fault: FaultSpec::none(),
        }
    }
}

/// Fleet simulation outcome.
#[derive(Debug, Clone)]
pub struct FleetResult {
    /// Merged per-request report across all replicas (each request is
    /// read from its *assigned* replica's final state).
    pub report: Report,
    /// Fault-injection, availability, and work-stealing accounting.
    pub fleet: FleetReport,
    /// Simulated end time.
    pub end_time: f64,
    /// Flight-recorder output (DESIGN.md §3.10); `None` unless the run
    /// was traced via [`simulate_fleet_traced`].
    pub telemetry: Option<TelemetryOut>,
    /// Fleet-queue events delivered (arrivals, steps, chunks, faults).
    pub events: u64,
    /// Self-profiler breakdown (DESIGN.md §3.11). `None` unless the run
    /// was profiled via [`simulate_fleet_observed`].
    pub profile: Option<ProfileReport>,
}

// ------------------------------------------------------------ event queue

/// Fleet event kinds: the three replica-local kinds of
/// `scheduler::EventKind` with a replica tag, plus the fault triple.
/// Ordering rides on the shared [`TimeQueue`] — the exact
/// (time, insertion-tie) contract of `scheduler::EventQueue`, so a
/// single-replica zero-fault fleet replays the same schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
enum FleetEventKind {
    Arrival(RequestId),
    RelaxedStep { replica: usize, inst: usize, seq: u64 },
    StrictStep { replica: usize, inst: usize, seq: u64 },
    TransferChunk { replica: usize, job: JobId, seq: u64 },
    CrashNotice { replica: usize, inst: InstanceRef },
    Crash { replica: usize, inst: InstanceRef, down_s: f64 },
    Recover { replica: usize, inst: InstanceRef },
}

// ------------------------------------------------------------ fleet router

/// Top-level class-aware admission router over the replica groups.
///
/// Tracks a per-replica outstanding-load score (online requests weigh
/// 1.0, offline [`OFFLINE_LOAD_WEIGHT`]) charged at assignment, moved on
/// steal, and discharged when the replica's action stream reports
/// [`Action::Complete`].
#[derive(Debug)]
struct FleetRouter {
    policy: RoutePolicy,
    load: Vec<f64>,
    rr_next: usize,
    rng: Pcg,
}

impl FleetRouter {
    fn new(policy: RoutePolicy, replicas: usize, seed: u64) -> Self {
        FleetRouter {
            policy,
            load: vec![0.0; replicas],
            rr_next: 0,
            rng: Pcg::new(seed, ROUTE_STREAM),
        }
    }

    /// Pick a replica from `live` (non-empty, ascending indices) and
    /// charge it `weight`.
    fn assign(&mut self, live: &[usize], weight: f64) -> usize {
        debug_assert!(!live.is_empty(), "routing needs a live replica");
        let pick = if live.len() == 1 {
            // Short-circuit without an RNG draw so fleets that only
            // *transiently* have one live replica stay deterministic
            // relative to their own schedule, and single-replica fleets
            // never touch the route stream at all.
            live[0]
        } else {
            match self.policy {
                RoutePolicy::RoundRobin => {
                    let r = live[self.rr_next % live.len()];
                    self.rr_next = (self.rr_next + 1) % live.len();
                    r
                }
                RoutePolicy::LeastLoaded => self.argmin(live),
                RoutePolicy::PowerOfTwo => {
                    let a = live[self.rng.below(live.len())];
                    let b = loop {
                        let c = live[self.rng.below(live.len())];
                        if c != a {
                            break c;
                        }
                    };
                    let (lo, hi) = if a < b { (a, b) } else { (b, a) };
                    // Ties break toward the lower index, like least-loaded.
                    if self.load[hi] < self.load[lo] {
                        hi
                    } else {
                        lo
                    }
                }
            }
        };
        self.load[pick] += weight;
        pick
    }

    /// Least-loaded replica among `live`; ties break toward the lowest
    /// index (deterministic).
    fn argmin(&self, live: &[usize]) -> usize {
        let mut best = live[0];
        for &r in &live[1..] {
            if self.load[r] < self.load[best] {
                best = r;
            }
        }
        best
    }

    fn transfer(&mut self, from: usize, to: usize, weight: f64) {
        self.load[from] = (self.load[from] - weight).max(0.0);
        self.load[to] += weight;
    }

    fn complete(&mut self, replica: usize, weight: f64) {
        self.load[replica] = (self.load[replica] - weight).max(0.0);
    }
}

// --------------------------------------------------------------- downtime

/// One instance-down window, closed on recovery (or at end of run).
#[derive(Debug, Clone, Copy)]
struct DownWindow {
    replica: usize,
    inst: InstanceRef,
    start: f64,
    end: Option<f64>,
}

// ------------------------------------------------------------------ fleet

/// A fleet of replica clusters under one router, with fault injection and
/// offline work stealing. Construct with [`Fleet::new`], optionally enable
/// `log`, then [`Fleet::run`].
#[derive(Debug)]
pub struct Fleet {
    cfg: FleetConfig,
    replicas: Vec<SchedulerCore>,
    queue: TimeQueue<FleetEventKind>,
    now: f64,
    horizon: f64,
    events: u64,
    router: FleetRouter,
    /// Owning replica per request id (updated on steal).
    assigned: Vec<usize>,
    /// Router load weight per request id.
    weights: Vec<f64>,
    windows: Vec<DownWindow>,
    total_instances: usize,
    skipped_faults: u64,
    steals: u64,
    stolen_tokens: u64,
    /// When `Some`, every (replica, action) pair the cores emit is
    /// appended — the observable stream the fleet property tests assert.
    pub log: Option<Vec<(usize, Action)>>,
    /// Flight recorder tapping the same replica-tagged stream (disabled
    /// by default).
    pub telemetry: TraceRecorder,
}

impl Fleet {
    pub fn new(trace: &Trace, cfg: &FleetConfig) -> Self {
        Self::new_with_queue(trace, cfg, QueueKind::Calendar)
    }

    /// Like [`Fleet::new`] but on an explicit queue implementation —
    /// `tests/queue_differential.rs` drives both kinds over identical
    /// faulted fleets to pin the ordering contract.
    pub fn new_with_queue(
        trace: &Trace,
        cfg: &FleetConfig,
        queue_kind: QueueKind,
    ) -> Self {
        let _p = obs::scope(Subsystem::Setup);
        assert!(cfg.fleet.replicas >= 1, "fleet needs at least one replica");
        let n = cfg.fleet.replicas;
        // Every replica core holds the full request table so ids index
        // directly; only the assigned replica ever sees a given arrival
        // (or adopts it via steal_in).
        let replicas: Vec<SchedulerCore> = (0..n)
            .map(|_| SchedulerCore::new(trace.requests.clone(), cfg.sim.core()))
            .collect();
        let total_instances = n
            * (replicas[0].cluster.relaxed.len()
                + replicas[0].cluster.strict.len());

        let mut queue = TimeQueue::with_kind(queue_kind);
        // Arrivals first, in trace order — ties 0..len match the
        // single-cluster `VirtualExecutor` exactly.
        for r in &trace.requests {
            queue.push(r.arrival, FleetEventKind::Arrival(r.id));
        }

        let horizon = trace.duration() + cfg.sim.drain_s;
        let weights: Vec<f64> = trace
            .requests
            .iter()
            .map(|r| match r.class {
                Class::Online => 1.0,
                Class::Offline => OFFLINE_LOAD_WEIGHT,
            })
            .collect();

        let mut fleet = Fleet {
            router: FleetRouter::new(cfg.fleet.route, n, cfg.sim.seed),
            cfg: cfg.clone(),
            replicas,
            queue,
            now: 0.0,
            horizon,
            events: 0,
            assigned: vec![usize::MAX; trace.requests.len()],
            weights,
            windows: Vec::new(),
            total_instances,
            skipped_faults: 0,
            steals: 0,
            stolen_tokens: 0,
            log: None,
            telemetry: TraceRecorder::disabled(),
        };
        fleet.schedule_faults();
        fleet
    }

    fn push(&mut self, time: f64, kind: FleetEventKind) {
        let _p = obs::scope(Subsystem::HeapPush);
        self.queue.push(time, kind);
    }

    /// Schedule the fault plan: explicit [`CrashEvent`]s verbatim, then a
    /// stochastic schedule pre-generated per instance from a dedicated
    /// seeded RNG stream (exponential up-gaps, fixed MTTR) — two runs with
    /// the same seed inject byte-identical faults.
    fn schedule_faults(&mut self) {
        let crashes = self.cfg.fault.crashes.clone();
        for c in &crashes {
            self.schedule_crash(c);
        }
        let Some(mtbf) = self.cfg.fault.mtbf else {
            return;
        };
        let n_relaxed = self.replicas[0].cluster.relaxed.len();
        let n_strict = self.replicas[0].cluster.strict.len();
        for replica in 0..self.cfg.fleet.replicas {
            for (pool, count) in [
                (FaultPool::Relaxed, n_relaxed),
                (FaultPool::Strict, n_strict),
            ] {
                for inst in 0..count {
                    let stream = FAULT_STREAM
                        + (replica as u64) * 1024
                        + if pool == FaultPool::Strict { 512 } else { 0 }
                        + inst as u64;
                    let mut rng = Pcg::new(self.cfg.sim.seed, stream);
                    let mut t = rng.exp(1.0 / mtbf.mean_s);
                    let mut scheduled = 0;
                    while t < self.horizon
                        && scheduled < MAX_FAULTS_PER_INSTANCE
                    {
                        self.schedule_crash(&CrashEvent {
                            at: t,
                            replica,
                            pool,
                            inst,
                            down_s: mtbf.mttr_s,
                            notice_s: mtbf.notice_s,
                        });
                        scheduled += 1;
                        t += mtbf.mttr_s + rng.exp(1.0 / mtbf.mean_s);
                    }
                }
            }
        }
    }

    fn schedule_crash(&mut self, c: &CrashEvent) {
        if c.replica >= self.cfg.fleet.replicas {
            self.skipped_faults += 1;
            return;
        }
        let inst = match c.pool {
            FaultPool::Relaxed => InstanceRef::Relaxed(c.inst),
            FaultPool::Strict => InstanceRef::Strict(c.inst),
        };
        if c.notice_s > 0.0 && c.at - c.notice_s > 0.0 {
            self.push(
                c.at - c.notice_s,
                FleetEventKind::CrashNotice {
                    replica: c.replica,
                    inst,
                },
            );
        }
        self.push(
            c.at,
            FleetEventKind::Crash {
                replica: c.replica,
                inst,
                down_s: c.down_s.max(1e-3),
            },
        );
    }

    /// Replay one core's action stream on the fleet clock — the
    /// `VirtualExecutor::apply` semantics with a replica tag — and
    /// discharge router load on completions.
    fn apply(&mut self, replica: usize, mut actions: Vec<Action>) {
        self.telemetry.observe(self.now, replica, &actions);
        for a in &actions {
            match *a {
                Action::StartStep {
                    inst,
                    predicted_latency,
                    seq,
                    ..
                } => {
                    let kind = match inst {
                        InstanceRef::Relaxed(i) => FleetEventKind::RelaxedStep {
                            replica,
                            inst: i,
                            seq,
                        },
                        InstanceRef::Strict(i) => FleetEventKind::StrictStep {
                            replica,
                            inst: i,
                            seq,
                        },
                    };
                    self.push(self.now + predicted_latency, kind);
                }
                Action::Preempt { inst, delay, seq } => {
                    self.push(
                        self.now + delay,
                        FleetEventKind::RelaxedStep {
                            replica,
                            inst,
                            seq,
                        },
                    );
                }
                Action::TransferChunk {
                    job,
                    predicted_latency,
                    seq,
                    ..
                } => {
                    self.push(
                        self.now + predicted_latency,
                        FleetEventKind::TransferChunk { replica, job, seq },
                    );
                }
                Action::Complete { req } => {
                    self.router
                        .complete(replica, self.weights[req as usize]);
                }
                _ => {}
            }
        }
        if let Some(log) = &mut self.log {
            // `drain` moves the items but keeps the vec's capacity for
            // the recycling below.
            log.extend(actions.drain(..).map(|a| (replica, a)));
        }
        self.replicas[replica].recycle_actions(actions);
    }

    /// Replicas whose relaxed pool (the admission side) has a live
    /// instance. Never empty: the crash skip rule refuses to take down the
    /// last live instance of a pool.
    fn live_replicas(&self) -> Vec<usize> {
        let live: Vec<usize> = (0..self.replicas.len())
            .filter(|&r| self.replicas[r].cluster.router.any_relaxed_up())
            .collect();
        debug_assert!(!live.is_empty(), "fault injection kept one live");
        live
    }

    fn on_arrival(&mut self, rid: RequestId) {
        let replica = {
            let _p = obs::scope(Subsystem::Fleet);
            let live = self.live_replicas();
            let replica =
                self.router.assign(&live, self.weights[rid as usize]);
            self.assigned[rid as usize] = replica;
            replica
        };
        let actions = {
            let _p = obs::scope(Subsystem::Scheduler);
            self.replicas[replica].on_arrival(self.now, rid)
        };
        self.apply(replica, actions);
    }

    /// Would crashing `inst` leave its pool with no live instance?
    fn is_last_live(&self, replica: usize, inst: InstanceRef) -> bool {
        let cluster = &self.replicas[replica].cluster;
        match inst {
            InstanceRef::Relaxed(_) => {
                cluster.relaxed.iter().filter(|i| !i.down).count() <= 1
            }
            InstanceRef::Strict(_) => {
                cluster.strict.iter().filter(|i| !i.down).count() <= 1
            }
        }
    }

    /// Does `inst` currently exist in `replica`'s pool vectors? Elastic
    /// repartitioning resizes pools mid-run, so a fault scheduled against
    /// the initial topology can dangle.
    fn in_range(&self, replica: usize, inst: InstanceRef) -> bool {
        let cluster = &self.replicas[replica].cluster;
        match inst {
            InstanceRef::Relaxed(i) => i < cluster.relaxed.len(),
            InstanceRef::Strict(i) => i < cluster.strict.len(),
        }
    }

    fn instance_flags(
        &self,
        replica: usize,
        inst: InstanceRef,
    ) -> (bool, bool) {
        let cluster = &self.replicas[replica].cluster;
        match inst {
            InstanceRef::Relaxed(i) => {
                (cluster.relaxed[i].down, cluster.relaxed[i].evacuating)
            }
            InstanceRef::Strict(i) => {
                (cluster.strict[i].down, cluster.strict[i].evacuating)
            }
        }
    }

    fn on_crash_notice(&mut self, replica: usize, inst: InstanceRef) {
        if !self.in_range(replica, inst) || self.is_last_live(replica, inst) {
            // Refused up front: don't evacuate an instance we won't kill.
            return;
        }
        let (down, evacuating) = self.instance_flags(replica, inst);
        if down || evacuating {
            return;
        }
        let actions = self.replicas[replica].on_crash_notice(self.now, inst);
        self.apply(replica, actions);
    }

    fn on_crash(&mut self, replica: usize, inst: InstanceRef, down_s: f64) {
        let skip = !self.in_range(replica, inst)
            || self.instance_flags(replica, inst).0
            || self.is_last_live(replica, inst);
        if skip {
            self.skipped_faults += 1;
            // A notice may have gone out before the skip condition arose
            // (e.g. the *other* instance crashed in between): stand the
            // evacuating instance back up or it stays excluded forever.
            if self.in_range(replica, inst) {
                let (down, evacuating) = self.instance_flags(replica, inst);
                if !down && evacuating {
                    let actions =
                        self.replicas[replica].on_crash_averted(self.now, inst);
                    self.apply(replica, actions);
                }
            }
            return;
        }
        let actions = self.replicas[replica].on_instance_down(self.now, inst);
        self.apply(replica, actions);
        self.windows.push(DownWindow {
            replica,
            inst,
            start: self.now,
            end: None,
        });
        self.push(
            self.now + down_s,
            FleetEventKind::Recover { replica, inst },
        );
    }

    fn on_recover(&mut self, replica: usize, inst: InstanceRef) {
        if !self.in_range(replica, inst)
            || !self.instance_flags(replica, inst).0
        {
            // The instance vanished in a repartition or was never downed
            // (its crash was skipped); nothing to recover.
            return;
        }
        let actions = self.replicas[replica].on_instance_up(self.now, inst);
        self.apply(replica, actions);
        for w in self.windows.iter_mut().rev() {
            if w.replica == replica && w.inst == inst && w.end.is_none() {
                w.end = Some(self.now);
                break;
            }
        }
    }

    /// Opportunistic cross-replica offline work stealing: a replica whose
    /// backlog is empty and whose relaxed pool has an idle live instance
    /// steals up to `steal_batch` tail entries from the replica with the
    /// deepest backlog. Deterministic (no RNG, fixed scan order) and
    /// never engaged by a single-replica fleet.
    fn try_steal(&mut self) {
        if self.cfg.fleet.replicas < 2 || self.cfg.fleet.steal_batch == 0 {
            return;
        }
        let _p = obs::scope(Subsystem::Fleet);
        for thief in 0..self.replicas.len() {
            if !self.replicas[thief].cluster.offline_backlog.is_empty() {
                continue;
            }
            let hungry = self.replicas[thief]
                .cluster
                .relaxed
                .iter()
                .any(|i| i.accepts_work() && i.is_idle());
            if !hungry {
                continue;
            }
            // Deepest backlog wins; ties break toward the lowest index.
            let victim = (0..self.replicas.len())
                .filter(|&v| v != thief)
                .max_by_key(|&v| {
                    let depth =
                        self.replicas[v].cluster.offline_backlog.len();
                    (depth, std::cmp::Reverse(v))
                });
            let Some(victim) = victim else { continue };
            // Leave the victim its FIFO head: stealing the whole backlog
            // would just move the starvation.
            for _ in 0..self.cfg.fleet.steal_batch {
                if self.replicas[victim].cluster.offline_backlog.len() < 2 {
                    break;
                }
                let Some((rid, state)) =
                    self.replicas[victim].steal_out(self.now)
                else {
                    break;
                };
                self.steals += 1;
                self.stolen_tokens += state.prompt_len as u64;
                self.router.transfer(
                    victim,
                    thief,
                    self.weights[rid as usize],
                );
                self.assigned[rid as usize] = thief;
                let actions =
                    self.replicas[thief].steal_in(self.now, rid, state);
                self.apply(thief, actions);
            }
        }
    }

    /// Drive the fleet to completion and aggregate the outcome.
    pub fn run(&mut self, trace: &Trace) -> FleetResult {
        loop {
            let ev = {
                let _p = obs::scope(Subsystem::HeapPop);
                match self.queue.pop() {
                    Some(ev) => ev,
                    None => break,
                }
            };
            if ev.time > self.horizon {
                break;
            }
            self.now = ev.time;
            self.events += 1;
            match ev.kind {
                FleetEventKind::Arrival(rid) => {
                    obs::count_event(EventClass::Arrival);
                    self.on_arrival(rid);
                }
                FleetEventKind::RelaxedStep { replica, inst, seq } => {
                    obs::count_event(EventClass::RelaxedStep);
                    let actions = {
                        let _p = obs::scope(Subsystem::Scheduler);
                        self.replicas[replica].on_step_end(
                            self.now,
                            InstanceRef::Relaxed(inst),
                            seq,
                        )
                    };
                    self.apply(replica, actions);
                }
                FleetEventKind::StrictStep { replica, inst, seq } => {
                    obs::count_event(EventClass::StrictStep);
                    let actions = {
                        let _p = obs::scope(Subsystem::Scheduler);
                        self.replicas[replica].on_step_end(
                            self.now,
                            InstanceRef::Strict(inst),
                            seq,
                        )
                    };
                    self.apply(replica, actions);
                }
                FleetEventKind::TransferChunk { replica, job, seq } => {
                    obs::count_event(EventClass::TransferChunk);
                    let actions = {
                        let _p = obs::scope(Subsystem::Transport);
                        self.replicas[replica]
                            .on_transfer_progress(self.now, job, seq)
                    };
                    self.apply(replica, actions);
                }
                FleetEventKind::CrashNotice { replica, inst } => {
                    obs::count_event(EventClass::CrashNotice);
                    let _p = obs::scope(Subsystem::Fleet);
                    self.on_crash_notice(replica, inst);
                }
                FleetEventKind::Crash {
                    replica,
                    inst,
                    down_s,
                } => {
                    obs::count_event(EventClass::Crash);
                    let _p = obs::scope(Subsystem::Fleet);
                    self.on_crash(replica, inst, down_s);
                }
                FleetEventKind::Recover { replica, inst } => {
                    obs::count_event(EventClass::Recover);
                    let _p = obs::scope(Subsystem::Fleet);
                    self.on_recover(replica, inst);
                }
            }
            self.try_steal();
            if self.telemetry.sample_due(self.now) {
                for r in 0..self.replicas.len() {
                    self.telemetry.sample_replica(
                        self.now,
                        r,
                        &self.replicas[r].cluster,
                        self.replicas[r].transport.links(),
                    );
                }
                self.telemetry.sample_tick(self.now, self.events);
            }
        }
        self.build_result(trace)
    }

    fn build_result(&mut self, trace: &Trace) -> FleetResult {
        let _p = obs::scope(Subsystem::Metrics);
        let end_time = self.now;
        let duration = trace.duration().max(1e-9);

        // Merge per-request outcomes from each request's assigned replica
        // — the only replica whose copy ever advanced. Unrouted requests
        // (the horizon passed before their arrival) are skipped entirely,
        // matching what a single cluster would have seen.
        // Downtime + availability. Open windows (still down at the end)
        // close at end_time.
        let mut downtime_inst_s = 0.0;
        for w in &self.windows {
            downtime_inst_s += w.end.unwrap_or(end_time) - w.start;
        }
        let denom = (self.total_instances as f64) * end_time;
        let availability = if denom > 0.0 {
            (1.0 - downtime_inst_s / denom).clamp(0.0, 1.0)
        } else {
            1.0
        };

        // Online latency during failover: requests finishing while any
        // instance was down anywhere in the fleet.
        let in_window = |t: f64| {
            self.windows
                .iter()
                .any(|w| t >= w.start && t <= w.end.unwrap_or(end_time))
        };

        let mut recorder = Recorder::new(&self.cfg.sim.serving.slo);
        let mut fo_ttft = LatencySummary::new();
        let mut fo_tpot = LatencySummary::new();
        let mut accounting_errors = 0u64;
        for r in &trace.requests {
            let replica = self.assigned[r.id as usize];
            if replica == usize::MAX {
                continue;
            }
            let cluster = &self.replicas[replica].cluster;
            let req = &cluster.requests[r.id as usize];
            recorder.record(req);
            self.telemetry.finalize_request(req);
            // No request silently lost: unfinished ⇒ still tracked by some
            // scheduling structure of its assigned replica.
            if req.finished_at.is_none() && !cluster.holds(r.id) {
                accounting_errors += 1;
            }
            // Failover latency accumulates in the same streaming pass —
            // no per-request record vector is ever materialized.
            if req.class == Class::Online {
                if let Some(fin) = req.finished_at {
                    if in_window(fin) {
                        if let Some(t) = req.ttft() {
                            fo_ttft.record(t);
                        }
                        if let Some(t) = req.avg_tpot() {
                            fo_tpot.record(t);
                        }
                    }
                }
            }
        }
        let report = recorder.report(duration);

        let sum = |f: fn(&crate::scheduler::ClusterState) -> u64| {
            self.replicas.iter().map(|c| f(&c.cluster)).sum::<u64>()
        };
        let fleet = FleetReport {
            replicas: self.cfg.fleet.replicas,
            crashes: sum(|c| c.crashes),
            recoveries: sum(|c| c.recoveries),
            skipped_faults: self.skipped_faults,
            availability,
            downtime_inst_s,
            crash_evictions: sum(|c| c.crash_evictions),
            recompute_tokens: sum(|c| c.crash_recompute_tokens),
            evacuated_tokens: sum(|c| c.crash_evac_tokens),
            steals: self.steals,
            stolen_tokens: self.stolen_tokens,
            failover_ttft: fo_ttft.summary(),
            failover_tpot: fo_tpot.summary(),
            accounting_errors,
        };

        FleetResult {
            report,
            fleet,
            end_time,
            telemetry: self.telemetry.finish(end_time),
            events: self.events,
            profile: None,
        }
    }

    /// Borrow a replica core (tests, post-run inspection).
    pub fn replica(&self, idx: usize) -> &SchedulerCore {
        &self.replicas[idx]
    }
}

/// Run the fleet simulation of `trace` under `cfg`.
pub fn simulate_fleet(trace: &Trace, cfg: &FleetConfig) -> FleetResult {
    Fleet::new(trace, cfg).run(trace)
}

/// [`simulate_fleet`] with an optional flight recorder attached to the
/// replica-tagged action stream; its output lands in
/// [`FleetResult::telemetry`].
pub fn simulate_fleet_traced(
    trace: &Trace,
    cfg: &FleetConfig,
    telemetry: Option<TelemetryOpts>,
) -> FleetResult {
    simulate_fleet_queued(trace, cfg, telemetry, false, QueueKind::Calendar)
}

/// [`simulate_fleet_traced`] with the self-profiler optionally armed
/// (DESIGN.md §3.11); the breakdown lands in [`FleetResult::profile`].
/// Probes are pure observers: `profile: true` leaves every deterministic
/// field byte-identical to an unprofiled same-seed run.
pub fn simulate_fleet_observed(
    trace: &Trace,
    cfg: &FleetConfig,
    telemetry: Option<TelemetryOpts>,
    profile: bool,
) -> FleetResult {
    simulate_fleet_queued(trace, cfg, telemetry, profile, QueueKind::Calendar)
}

/// [`simulate_fleet_observed`] on an explicit time-queue implementation.
/// Both kinds honor the identical ordering contract, so every
/// deterministic output field is byte-identical across them — the fleet
/// half of the queue-swap differential suite.
pub fn simulate_fleet_queued(
    trace: &Trace,
    cfg: &FleetConfig,
    telemetry: Option<TelemetryOpts>,
    profile: bool,
    queue_kind: QueueKind,
) -> FleetResult {
    if profile {
        obs::enable();
    }
    let mut fleet = Fleet::new_with_queue(trace, cfg, queue_kind);
    if let Some(opts) = telemetry {
        let mut rec = TraceRecorder::flight(opts);
        rec.set_horizon(fleet.horizon);
        if let Some(wp) = opts.watch {
            rec.arm_watch(crate::watch::Watchdog::new(wp, &cfg.sim.serving));
        }
        rec.register_requests(&trace.requests);
        for r in 0..cfg.fleet.replicas {
            rec.register_replica(
                r,
                fleet.replicas[r].cluster.relaxed.len(),
                fleet.replicas[r].cluster.strict.len(),
            );
        }
        fleet.telemetry = rec;
    }
    let mut result = fleet.run(trace);
    if profile {
        result.profile = Some(obs::take_report());
    }
    result
}

/// Compose the machine-readable `--json-out` object for a fleet run:
/// config echo, report sections, optional telemetry, optional profile.
/// The CLI layers the `meta` header on top; everything except `profile`
/// is deterministic for a fixed seed.
pub fn result_json(cfg: &FleetConfig, res: &FleetResult) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![
        ("policy", Json::Str(cfg.sim.policy.to_string())),
        ("pool_policy", Json::Str(cfg.sim.serving.pool.to_string())),
        (
            "chunk_tokens",
            Json::Str(cfg.sim.serving.chunk_tokens.to_string()),
        ),
        ("fleet_spec", cfg.fleet.to_json()),
        ("fault_spec", cfg.fault.to_json()),
        ("seed", Json::Num(cfg.sim.seed as f64)),
        ("events", Json::Num(res.events as f64)),
        ("report", res.report.to_json()),
        ("fleet", res.fleet.to_json()),
    ];
    if let Some(tel) = &res.telemetry {
        pairs.push(("timeline", tel.timeline.clone()));
        pairs.push(("attribution", tel.attribution.clone()));
        if let Some(inc) = &tel.incidents {
            pairs.push(("incidents", inc.clone()));
        }
    }
    if let Some(profile) = &res.profile {
        pairs.push(("profile", profile.to_json()));
    }
    Json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;
    use crate::coordinator::Policy;
    use crate::trace::{datasets::DatasetProfile, generator::online_trace};

    fn small_cfg() -> FleetConfig {
        let mut serving = ServingConfig::preset_7b();
        // Two instances per pool so a crash is never last-live-refused.
        serving.cluster.relaxed_instances = 2;
        serving.cluster.strict_instances = 2;
        let mut sim = SimConfig::new(serving, Policy::Ooco);
        sim.drain_s = 120.0;
        FleetConfig::new(sim)
    }

    fn small_trace() -> Trace {
        online_trace(DatasetProfile::azure_conv(), 1.0, 60.0, 11)
    }

    #[test]
    fn single_replica_no_fault_drains() {
        let trace = small_trace();
        let res = simulate_fleet(&trace, &small_cfg());
        assert_eq!(res.fleet.crashes, 0);
        assert_eq!(res.fleet.steals, 0);
        assert_eq!(res.fleet.accounting_errors, 0);
        assert!((res.fleet.availability - 1.0).abs() < 1e-12);
        assert!(res.report.online_finished > 0);
    }

    #[test]
    fn scheduled_crash_fires_and_recovers() {
        let trace = small_trace();
        let mut cfg = small_cfg();
        cfg.fault = "crash(at=10,inst=1,down=30)".parse().unwrap();
        let res = simulate_fleet(&trace, &cfg);
        assert_eq!(res.fleet.crashes, 1);
        assert_eq!(res.fleet.recoveries, 1);
        assert!(res.fleet.availability < 1.0);
        assert!(res.fleet.downtime_inst_s > 29.0);
        assert_eq!(res.fleet.accounting_errors, 0);
    }

    #[test]
    fn crash_on_last_live_instance_is_skipped() {
        let trace = small_trace();
        let mut cfg = small_cfg();
        // Two crashes against the same two-instance relaxed pool, the
        // second while the first is still down: it must be refused.
        cfg.fault = "crash(at=10,inst=0,down=50); crash(at=20,inst=1,down=50)"
            .parse()
            .unwrap();
        let res = simulate_fleet(&trace, &cfg);
        assert_eq!(res.fleet.crashes, 1);
        assert_eq!(res.fleet.skipped_faults, 1);
        assert_eq!(res.fleet.accounting_errors, 0);
    }

    #[test]
    fn multi_replica_routes_and_steals() {
        let trace = crate::trace::generator::offline_trace(
            DatasetProfile::azure_code(),
            4.0,
            60.0,
            7,
        );
        let mut cfg = small_cfg();
        cfg.fleet.replicas = 2;
        let res = simulate_fleet(&trace, &cfg);
        assert_eq!(res.fleet.accounting_errors, 0);
        assert!(res.report.offline_finished > 0);
    }
}
