//! Real serving engine over the PJRT runtime (the end-to-end proof that
//! L1 Pallas kernels -> L2 JAX model -> L3 rust coordinator compose).
//!
//! Since the `SchedulerCore` redesign the engine is the wall-clock
//! [`crate::scheduler::Executor`]: every scheduling decision — routing,
//! gating, migration (Algorithm 1), SLO-aware mix decoding (Algorithm 2 on
//! *measured-calibrated* perf-model predictions), eviction — is made by the
//! exact same [`crate::scheduler::SchedulerCore`] the simulator drives;
//! only the clock and the execution substrate differ. [`EngineExecutor`]
//! replays the trace through an mpsc feeder thread, executes the core's
//! `StartStep` actions on the real PJRT executables (XLA handles stay on
//! one thread), and reports honest wall-clock numbers.
//!
//! Differences from the virtual substrate, by necessity:
//! - layer-level preemption is approximated at step granularity (a single
//!   CPU process cannot abort a running XLA execution mid-flight): the
//!   preempted prefill still runs, but the core discards its work;
//! - both pools share one CPU, so "strict" latency includes interleaved
//!   prefill time.
//!
//! KV transfers are *not* instantaneous anymore: the core's transport
//! engine times every chunk, and this executor performs the corresponding
//! real work — each [`Action::TransferChunk`] copies that chunk's range of
//! the request's KV host vectors into a per-job staging buffer, which is
//! swapped in when [`Action::TransferDone`] lands. Chunk copies interleave
//! with model steps on the same agenda, so transfers genuinely overlap
//! decode execution.

use std::collections::{HashMap, VecDeque};
use std::path::Path;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;

use crate::config::{
    ChunkMode, ClusterSpec, HardwareProfile, PoolPolicy, PrefixSpec,
    SchedulerParams, ServingConfig, SloSpec, TransportSpec,
};
use crate::coordinator::{Ablation, OverloadMode, Policy};
use crate::instance::{PrefillSegment, StepKind};
use crate::metrics::{
    ChunkReport, PoolReport, PrefixReport, Recorder, Report,
    TransportReport,
};
use crate::perfmodel::BatchStats;
use crate::perfmodel::{calibrate, PerfModel, Sample, SampleKind};
use crate::request::{Class, Request, RequestId};
use crate::runtime::{DecodeEntry, KvBuf, Runtime};
use crate::scheduler::{
    Action, CoreConfig, ExecStats, Executor, InstanceRef, SchedulerCore,
};
use crate::trace::Trace;
use crate::transport::JobId;
use crate::util::rng::Pcg;

/// Engine parameters.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub policy: Policy,
    pub slo: SloSpec,
    pub sched: SchedulerParams,
    /// Cluster shape (both pools share the one CPU; multi-instance shapes
    /// exercise routing and the elastic pool manager on real execution).
    pub cluster: ClusterSpec,
    /// Elastic pool-manager policy (needs a `cluster` with more than one
    /// instance in some pool to ever repartition).
    pub pool: PoolPolicy,
    /// Prefix-sharing KV cache (DESIGN.md §3.7). The core shares and
    /// prices cached blocks; this substrate still recomputes them
    /// (documented divergence).
    pub prefix: PrefixSpec,
    /// Chunked-prefill iteration model (DESIGN.md §3.8). Partial chunks
    /// do no model work on this substrate; the full prompt runs at the
    /// final chunk (documented divergence).
    pub chunk_tokens: ChunkMode,
    /// Wall-clock compression: trace time / `time_scale` (e.g. 10 replays a
    /// 600 s trace in 60 s).
    pub time_scale: f64,
    /// Hard cap on generated tokens per request (keeps runs bounded).
    pub max_output: usize,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: Policy::Ooco,
            // CPU-scale SLOs for the tiny model (calibrated magnitudes).
            slo: SloSpec {
                ttft: 2.0,
                tpot: 0.25,
                violation_threshold: 0.03,
            },
            sched: SchedulerParams::default(),
            cluster: ClusterSpec {
                relaxed_instances: 1,
                strict_instances: 1,
            },
            pool: PoolPolicy::Static,
            prefix: PrefixSpec::default(),
            chunk_tokens: ChunkMode::Auto,
            time_scale: 1.0,
            max_output: 32,
            seed: 0,
        }
    }
}

/// Outcome of a serving run.
#[derive(Debug)]
pub struct EngineOutcome {
    pub report: Report,
    pub wall_s: f64,
    pub prefills: u64,
    pub strict_steps: u64,
    pub relaxed_steps: u64,
    pub online_tokens: u64,
    pub offline_tokens: u64,
    /// Measured (batch/seq, latency) samples collected during the run —
    /// input for perf-model calibration and accuracy benches.
    pub samples: Vec<Sample>,
    /// The CPU-calibrated perf model used for Algorithm 2 during the run.
    pub perf_model: PerfModel,
    /// KV transport accounting (chunk copies the engine actually did).
    pub transport: TransportReport,
    /// Elastic pool-manager accounting (plans, flips, transitions).
    pub pool: PoolReport,
    /// Prefix-sharing cache accounting (hits, savings, evictions).
    pub prefix: PrefixReport,
    /// Chunked-prefill iteration accounting (DESIGN.md §3.8).
    pub chunk: ChunkReport,
}

/// Live execution state of one request on the real substrate: its KV cache
/// block and decode cursor. Scheduling state lives in the core's
/// `ClusterState`; this is substrate-only.
struct Live {
    kv: KvBuf,
    last_token: i32,
    position: i32,
    class: Class,
}

/// A `StartStep` work order queued for synchronous execution.
#[derive(Debug, Clone)]
struct PendingStep {
    inst: InstanceRef,
    kind: StepKind,
    participants: Vec<RequestId>,
    /// Chunked-prefill segments of a composed iteration (DESIGN.md §3.8).
    prefill: Vec<PrefillSegment>,
    seq: u64,
}

/// One agenda item: a model step or a KV-transfer chunk copy.
#[derive(Debug, Clone)]
enum PendingWork {
    Step(PendingStep),
    Chunk { job: JobId, chunk: usize, seq: u64 },
}

/// Destination buffer of an in-flight KV transfer, filled chunk-by-chunk.
struct Staging {
    req: RequestId,
    k: Vec<f32>,
    v: Vec<f32>,
    chunks: usize,
}

/// Probe the runtime and fit a CPU hardware profile for the tiny model —
/// the engine's analog of the paper's Table 4 profiling step.
pub fn calibrate_runtime(rt: &Runtime) -> Result<(PerfModel, Vec<Sample>)> {
    let model = tiny_model_spec(rt);
    let mut samples = Vec::new();
    let mut rng = Pcg::seeded(7);
    // Prefill probes across buckets.
    for &s in &rt.manifest.prefill_buckets.clone() {
        let len = s.saturating_sub(4).max(1);
        let toks: Vec<i32> =
            (0..len).map(|_| rng.below(rt.manifest.vocab) as i32).collect();
        let t0 = Instant::now();
        let _ = rt.prefill(&toks)?;
        samples.push(Sample {
            kind: SampleKind::Prefill { prompt_len: len },
            latency_s: t0.elapsed().as_secs_f64(),
        });
    }
    // Decode probes across buckets.
    let kv_elems = rt.kv_elems();
    for &b in &rt.manifest.decode_buckets.clone() {
        let mut kvs: Vec<KvBuf> = (0..b).map(|_| KvBuf::zeros(kv_elems)).collect();
        let mut entries: Vec<DecodeEntry> = kvs
            .iter_mut()
            .map(|kv| DecodeEntry {
                token: 1,
                position: 64,
                kv,
            })
            .collect();
        let t0 = Instant::now();
        let _ = rt.decode(&mut entries)?;
        samples.push(Sample {
            kind: SampleKind::Decode {
                batch: BatchStats::new(b, b * 64),
            },
            latency_s: t0.elapsed().as_secs_f64(),
        });
    }
    let fitted = calibrate(&model, &HardwareProfile::cpu_tiny(), &samples, 10);
    Ok((PerfModel::new(model, fitted), samples))
}

fn tiny_model_spec(rt: &Runtime) -> crate::config::ModelSpec {
    let m = &rt.manifest;
    crate::config::ModelSpec {
        name: "tiny".into(),
        layers: m.layers,
        hidden: m.hidden,
        q_heads: m.q_heads,
        kv_heads: m.kv_heads,
        head_dim: m.head_dim,
        ffn: m.ffn,
        vocab: m.vocab,
        bytes_per_value: 4.0,
        tensor_parallel: 1,
    }
}

/// Serve a trace end-to-end with real model execution.
pub fn serve_trace(
    artifacts_dir: &Path,
    trace: &Trace,
    cfg: &EngineConfig,
) -> Result<EngineOutcome> {
    let rt = Runtime::load(artifacts_dir)?;
    serve_trace_with_runtime(&rt, trace, cfg)
}

/// Serve a trace through the unified scheduler: calibrate the perf model,
/// build a [`SchedulerCore`] over the (runtime-clamped) requests, and drive
/// it with the wall-clock [`EngineExecutor`].
pub fn serve_trace_with_runtime(
    rt: &Runtime,
    trace: &Trace,
    cfg: &EngineConfig,
) -> Result<EngineOutcome> {
    let (pm, samples) = calibrate_runtime(rt)?;

    // Clamp requests to the tiny runtime's shape limits up front so the
    // core's accounting matches what actually executes.
    let smax = rt.manifest.smax;
    let reserve = cfg.max_output.max(1) + 1;
    let mut requests = trace.requests.clone();
    for r in &mut requests {
        r.prompt_len = r.prompt_len.min(smax.saturating_sub(reserve)).max(1);
        r.output_len = r.output_len.min(cfg.max_output).max(1);
    }

    let core_cfg = CoreConfig {
        serving: ServingConfig {
            model: tiny_model_spec(rt),
            transport: TransportSpec::for_hardware(&pm.hw),
            hardware: pm.hw.clone(),
            slo: cfg.slo,
            sched: cfg.sched.clone(),
            cluster: cfg.cluster,
            pool: cfg.pool,
            prefix: cfg.prefix,
            chunk_tokens: cfg.chunk_tokens,
        },
        policy: cfg.policy,
        ablation: Ablation::full(),
        overload_mode: OverloadMode::BestEffort,
        block_tokens: 16,
        seed: cfg.seed,
    };
    let mut core = SchedulerCore::with_perf_model(requests, core_cfg, pm.clone());

    let mut executor = EngineExecutor::new(rt, trace, cfg.clone(), samples);
    executor.run(&mut core)?;
    Ok(executor.into_outcome(&core, trace, pm))
}

/// Wall-clock [`Executor`] over the real PJRT runtime.
pub struct EngineExecutor<'rt> {
    rt: &'rt Runtime,
    cfg: EngineConfig,
    start: Instant,
    rx: mpsc::Receiver<Request>,
    feeder: Option<std::thread::JoinHandle<()>>,
    /// Per-request substrate state (KV buffer + decode cursor).
    lives: HashMap<RequestId, Live>,
    /// Per-job transfer staging buffers (chunk copies land here).
    staging: HashMap<JobId, Staging>,
    /// Work orders (steps + transfer chunks) awaiting synchronous execution.
    pending: VecDeque<PendingWork>,
    rng: Pcg,
    feeding: bool,
    events: u64,
    // ---- run statistics ----
    prefills: u64,
    strict_steps: u64,
    relaxed_steps: u64,
    online_tokens: u64,
    offline_tokens: u64,
    samples: Vec<Sample>,
}

impl<'rt> EngineExecutor<'rt> {
    /// Start the feeder thread replaying `trace` arrivals in compressed
    /// wall-clock time; `samples` seeds the measurement log (calibration
    /// probes).
    pub fn new(
        rt: &'rt Runtime,
        trace: &Trace,
        cfg: EngineConfig,
        samples: Vec<Sample>,
    ) -> Self {
        let (tx, rx) = mpsc::channel::<Request>();
        let feed: Vec<Request> = trace.requests.clone();
        let scale = cfg.time_scale.max(1e-9);
        let feeder = std::thread::spawn(move || {
            let start = Instant::now();
            for r in feed {
                let due = r.arrival / scale;
                let now = start.elapsed().as_secs_f64();
                if due > now {
                    std::thread::sleep(std::time::Duration::from_secs_f64(
                        due - now,
                    ));
                }
                if tx.send(r).is_err() {
                    return;
                }
            }
        });
        let seed = cfg.seed;
        EngineExecutor {
            rt,
            cfg,
            start: Instant::now(),
            rx,
            feeder: Some(feeder),
            lives: HashMap::new(),
            staging: HashMap::new(),
            pending: VecDeque::new(),
            rng: Pcg::new(seed, 616),
            feeding: true,
            events: 0,
            prefills: 0,
            strict_steps: 0,
            relaxed_steps: 0,
            online_tokens: 0,
            offline_tokens: 0,
            samples,
        }
    }

    /// Interpret the core's actions on the real substrate. Timed work
    /// (steps, transfer chunks) joins the agenda; notifications manage the
    /// per-request substrate resources.
    fn apply(&mut self, actions: Vec<Action>) {
        for a in actions {
            match a {
                Action::StartStep {
                    inst,
                    kind,
                    participants,
                    prefill,
                    seq,
                    ..
                } => {
                    self.pending.push_back(PendingWork::Step(PendingStep {
                        inst,
                        kind,
                        participants,
                        prefill,
                        seq,
                    }));
                }
                Action::Preempt { inst, seq, .. } => {
                    // Step-granularity approximation: the preempted prefill
                    // cannot be aborted mid-execution, but the core already
                    // discarded its work — re-tag the queued step so its
                    // completion delivers the superseding sequence id.
                    for p in self.pending.iter_mut() {
                        if let PendingWork::Step(p) = p {
                            if p.inst == InstanceRef::Relaxed(inst) {
                                p.seq = seq;
                            }
                        }
                    }
                }
                Action::TransferStart { job, req, chunks, .. } => {
                    // Allocate the destination buffer the chunk copies fill.
                    if let Some(live) = self.lives.get(&req) {
                        self.staging.insert(
                            job,
                            Staging {
                                req,
                                k: vec![0.0; live.kv.k.len()],
                                v: vec![0.0; live.kv.v.len()],
                                chunks,
                            },
                        );
                    }
                }
                Action::TransferChunk { job, chunk, seq, .. } => {
                    self.pending
                        .push_back(PendingWork::Chunk { job, chunk, seq });
                }
                Action::TransferDone { job, req, .. } => {
                    // The whole cache has been copied: the staging buffer
                    // becomes the request's live KV at its new home.
                    if let Some(st) = self.staging.remove(&job) {
                        if let Some(live) = self.lives.get_mut(&req) {
                            live.kv.k = st.k;
                            live.kv.v = st.v;
                        }
                    }
                }
                Action::TransferCancel { job, .. } => {
                    self.staging.remove(&job);
                }
                Action::Evict { req, .. } => {
                    // KV dropped for recompute; the core re-prefills later.
                    self.lives.remove(&req);
                }
                Action::Complete { req } => {
                    self.lives.remove(&req);
                }
                // Cluster-level notifications: no per-request substrate
                // resources to manage (pool flips move whole instances,
                // whose residents were already streamed off via the
                // transfer actions above). Prefix-cache events are
                // accounting-only here — this substrate recomputes cached
                // prefixes instead of sharing physical KV (DESIGN.md §3.7
                // divergence table).
                Action::Migrate { .. }
                | Action::Admit { .. }
                | Action::PrefixResolve { .. }
                | Action::PrefixEvict { .. }
                | Action::RepartitionPlan { .. }
                | Action::RoleChange { .. } => {}
                // Fleet fault injection is a simulator-only facility; this
                // substrate never receives crash events (DESIGN.md §3.9
                // divergence table). Per-request teardown, were one ever
                // delivered, rides the Evict/TransferCancel actions above.
                Action::InstanceDown { .. } | Action::InstanceUp { .. } => {}
            }
        }
    }

    /// Execute one StartStep work order on the runtime, then report the
    /// step boundary back to the core.
    fn execute(
        &mut self,
        core: &mut SchedulerCore,
        step: PendingStep,
    ) -> Result<()> {
        match step.kind {
            StepKind::PrefillOnline | StepKind::PrefillOffline => {
                self.exec_prefill(core, &step.participants)?;
            }
            StepKind::DecodeRelaxed | StepKind::DecodeStrict => {
                self.exec_decode(&step.participants)?;
            }
            StepKind::Composed => {
                // Composed iteration (DESIGN.md §3.8): decode every
                // participant, and run the prefill of each request whose
                // *final* chunk lands this step. The AOT prefill
                // executables take whole prompts, so partial chunks do no
                // model work here and the full prompt runs at the last
                // chunk — a documented substrate divergence (the core
                // prices chunks individually; this executor pays the cost
                // where the KV materializes).
                let finishing: Vec<RequestId> = step
                    .prefill
                    .iter()
                    .filter(|s| s.last)
                    .map(|s| s.req)
                    .collect();
                if !finishing.is_empty() {
                    self.exec_prefill(core, &finishing)?;
                }
                if !step.participants.is_empty() {
                    self.exec_decode(&step.participants)?;
                }
            }
            StepKind::Warm => {
                // Role-transition warm-up: no model work on this substrate;
                // the step boundary below reports it complete.
            }
        }
        match step.inst {
            InstanceRef::Relaxed(_) => self.relaxed_steps += 1,
            InstanceRef::Strict(_) => self.strict_steps += 1,
        }
        let now = self.now();
        self.events += 1;
        let actions = core.on_step_end(now, step.inst, step.seq);
        self.apply(actions);
        Ok(())
    }

    /// Perform one transfer chunk: copy its range of the source KV into the
    /// job's staging buffer, then report progress to the core.
    fn execute_chunk(
        &mut self,
        core: &mut SchedulerCore,
        job: JobId,
        chunk: usize,
        seq: u64,
    ) {
        if let Some(st) = self.staging.get_mut(&job) {
            if let Some(live) = self.lives.get(&st.req) {
                let len = st.k.len().min(live.kv.k.len());
                let chunks = st.chunks.max(1);
                let lo = chunk.min(chunks) * len / chunks;
                let hi = (chunk + 1).min(chunks) * len / chunks;
                st.k[lo..hi].copy_from_slice(&live.kv.k[lo..hi]);
                st.v[lo..hi].copy_from_slice(&live.kv.v[lo..hi]);
            }
        }
        let now = self.now();
        self.events += 1;
        let actions = core.on_transfer_progress(now, job, seq);
        self.apply(actions);
    }

    /// Run each listed request's (re-)prefill through the runtime.
    fn exec_prefill(
        &mut self,
        core: &mut SchedulerCore,
        rids: &[RequestId],
    ) -> Result<()> {
        let smax = self.rt.manifest.smax;
        let vocab = self.rt.manifest.vocab;
        let largest = self
            .rt
            .manifest
            .prefill_buckets
            .last()
            .copied()
            .unwrap_or(smax);
        for &rid in rids {
            let (len, class) = {
                let req = &core.cluster.requests[rid as usize];
                (
                    req.recompute_len()
                        .min(largest)
                        .min(smax.saturating_sub(2))
                        .max(1),
                    req.class,
                )
            };
            let toks: Vec<i32> =
                (0..len).map(|_| self.rng.below(vocab) as i32).collect();
            let t0 = Instant::now();
            let out = self.rt.prefill(&toks)?;
            self.samples.push(Sample {
                kind: SampleKind::Prefill { prompt_len: len },
                latency_s: t0.elapsed().as_secs_f64(),
            });
            self.prefills += 1;
            // The prefill's next-token prediction is the first output token.
            match class {
                Class::Online => self.online_tokens += 1,
                Class::Offline => self.offline_tokens += 1,
            }
            let last = argmax(&out.logits);
            self.lives.insert(
                rid,
                Live {
                    kv: out.kv,
                    last_token: last,
                    position: len as i32,
                    class,
                },
            );
        }
        Ok(())
    }

    /// Run one decode iteration over the listed participants, chunked to
    /// the runtime's largest decode bucket. Every participant advances one
    /// token, matching the core's step semantics.
    fn exec_decode(&mut self, rids: &[RequestId]) -> Result<()> {
        let max_batch = self.rt.max_decode_batch().max(1);
        let smax = self.rt.manifest.smax as i32;
        for chunk in rids.chunks(max_batch) {
            let mut batch: Vec<(RequestId, Live)> = chunk
                .iter()
                .filter_map(|&rid| self.lives.remove(&rid).map(|l| (rid, l)))
                .collect();
            if batch.is_empty() {
                continue;
            }
            let mut stats = BatchStats::empty();
            let mut entries: Vec<DecodeEntry> =
                Vec::with_capacity(batch.len());
            for (_, l) in batch.iter_mut() {
                stats = stats.with(l.position as usize);
                entries.push(DecodeEntry {
                    token: l.last_token,
                    position: l.position,
                    kv: &mut l.kv,
                });
            }
            let t0 = Instant::now();
            let logits = self.rt.decode(&mut entries)?;
            let lat = t0.elapsed().as_secs_f64();
            drop(entries);
            self.samples.push(Sample {
                kind: SampleKind::Decode { batch: stats },
                latency_s: lat,
            });
            for (i, (_, l)) in batch.iter_mut().enumerate() {
                l.last_token = argmax(&logits[i]);
                l.position = (l.position + 1).min(smax - 1);
                match l.class {
                    Class::Online => self.online_tokens += 1,
                    Class::Offline => self.offline_tokens += 1,
                }
            }
            for (rid, l) in batch {
                self.lives.insert(rid, l);
            }
        }
        Ok(())
    }

    /// Consume the executor into the run outcome, reading final request
    /// state from the core.
    pub fn into_outcome(
        mut self,
        core: &SchedulerCore,
        trace: &Trace,
        pm: PerfModel,
    ) -> EngineOutcome {
        if let Some(f) = self.feeder.take() {
            f.join().ok();
        }
        let mut recorder = Recorder::new(&self.cfg.slo);
        for r in &core.cluster.requests {
            recorder.record(r);
        }
        let duration = trace.duration().max(1e-9);
        EngineOutcome {
            report: recorder.report(duration),
            transport: core.transport_report(duration),
            pool: core.pool_report(),
            prefix: core.prefix_report(),
            chunk: core.chunk_report(),
            wall_s: self.start.elapsed().as_secs_f64(),
            prefills: self.prefills,
            strict_steps: self.strict_steps,
            relaxed_steps: self.relaxed_steps,
            online_tokens: self.online_tokens,
            offline_tokens: self.offline_tokens,
            samples: self.samples,
            perf_model: pm,
        }
    }
}

impl Executor for EngineExecutor<'_> {
    /// Wall-clock seconds since the run started, scaled back to trace time
    /// so SLO semantics match the trace's arrival process.
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * self.cfg.time_scale.max(1e-9)
    }

    fn run(&mut self, core: &mut SchedulerCore) -> Result<ExecStats> {
        loop {
            // ---- intake: deliver arrivals to the core ----
            loop {
                match self.rx.try_recv() {
                    Ok(r) => {
                        let now = self.now();
                        self.events += 1;
                        let actions = core.on_arrival(now, r.id);
                        self.apply(actions);
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        self.feeding = false;
                        break;
                    }
                }
            }

            // ---- execute the next work item the core scheduled ----
            if let Some(work) = self.pending.pop_front() {
                match work {
                    PendingWork::Step(step) => self.execute(core, step)?,
                    PendingWork::Chunk { job, chunk, seq } => {
                        self.execute_chunk(core, job, chunk, seq)
                    }
                }
            } else if !self.feeding {
                // No runnable work and no more arrivals: drained (or
                // stalled on capacity, which matches simulator semantics).
                break;
            } else {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
        Ok(ExecStats {
            end_time: self.now(),
            events: self.events,
        })
    }
}

fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[best] {
            best = i;
        }
    }
    best as i32
}
