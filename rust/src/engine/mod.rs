//! Real serving engine over the PJRT runtime (the end-to-end proof that
//! L1 Pallas kernels -> L2 JAX model -> L3 rust coordinator compose).
//!
//! One process hosts the two logical pools of the latency-constraint
//! disaggregated architecture: a latency-relaxed pool (prefill + offline
//! decode) and a latency-strict pool (online decode + SLO-bounded offline
//! mix-in, Algorithm 2 on *measured-calibrated* perf-model predictions).
//! A feeder thread replays the trace in wall-clock time through an mpsc
//! channel; the engine loop owns the PJRT executables (XLA handles stay on
//! one thread) and steps both pools.
//!
//! Differences from the simulator, by necessity of the substrate:
//! - layer-level preemption is approximated at step granularity (a single
//!   CPU process cannot abort a running XLA execution mid-flight);
//! - both pools share one CPU, so "strict" latency includes interleaved
//!   prefill time — the engine reports honest wall-clock numbers.

use std::collections::VecDeque;
use std::path::Path;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;

use crate::config::{HardwareProfile, SchedulerParams, SloSpec};
use crate::coordinator::{select_decode_batch, Candidate, Policy};
use crate::metrics::{Recorder, Report};
use crate::perfmodel::{calibrate, PerfModel, Sample, SampleKind};
use crate::perfmodel::BatchStats;
use crate::request::{Class, Request};
use crate::runtime::{DecodeEntry, KvBuf, Runtime};
use crate::trace::Trace;
use crate::util::rng::Pcg;

/// Engine parameters.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub policy: Policy,
    pub slo: SloSpec,
    pub sched: SchedulerParams,
    /// Wall-clock compression: trace time / `time_scale` (e.g. 10 replays a
    /// 600 s trace in 60 s).
    pub time_scale: f64,
    /// Hard cap on generated tokens per request (keeps runs bounded).
    pub max_output: usize,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: Policy::Ooco,
            // CPU-scale SLOs for the tiny model (calibrated magnitudes).
            slo: SloSpec {
                ttft: 2.0,
                tpot: 0.25,
                violation_threshold: 0.03,
            },
            sched: SchedulerParams::default(),
            time_scale: 1.0,
            max_output: 32,
            seed: 0,
        }
    }
}

/// Outcome of a serving run.
#[derive(Debug)]
pub struct EngineOutcome {
    pub report: Report,
    pub wall_s: f64,
    pub prefills: u64,
    pub strict_steps: u64,
    pub relaxed_steps: u64,
    pub online_tokens: u64,
    pub offline_tokens: u64,
    /// Measured (batch/seq, latency) samples collected during the run —
    /// input for perf-model calibration and accuracy benches.
    pub samples: Vec<Sample>,
    /// The CPU-calibrated perf model used for Algorithm 2 during the run.
    pub perf_model: PerfModel,
}

struct Live {
    req: Request,
    /// Prompt token ids (kept for debugging / future detokenization).
    #[allow(dead_code)]
    tokens: Vec<i32>,
    kv: KvBuf,
    last_token: i32,
    position: i32,
}

/// Probe the runtime and fit a CPU hardware profile for the tiny model —
/// the engine's analog of the paper's Table 4 profiling step.
pub fn calibrate_runtime(rt: &Runtime) -> Result<(PerfModel, Vec<Sample>)> {
    let model = tiny_model_spec(rt);
    let mut samples = Vec::new();
    let mut rng = Pcg::seeded(7);
    // Prefill probes across buckets.
    for &s in &rt.manifest.prefill_buckets.clone() {
        let len = s.saturating_sub(4).max(1);
        let toks: Vec<i32> =
            (0..len).map(|_| rng.below(rt.manifest.vocab) as i32).collect();
        let t0 = Instant::now();
        let _ = rt.prefill(&toks)?;
        samples.push(Sample {
            kind: SampleKind::Prefill { prompt_len: len },
            latency_s: t0.elapsed().as_secs_f64(),
        });
    }
    // Decode probes across buckets.
    let kv_elems = rt.kv_elems();
    for &b in &rt.manifest.decode_buckets.clone() {
        let mut kvs: Vec<KvBuf> = (0..b).map(|_| KvBuf::zeros(kv_elems)).collect();
        let mut entries: Vec<DecodeEntry> = kvs
            .iter_mut()
            .map(|kv| DecodeEntry {
                token: 1,
                position: 64,
                kv,
            })
            .collect();
        let t0 = Instant::now();
        let _ = rt.decode(&mut entries)?;
        samples.push(Sample {
            kind: SampleKind::Decode {
                batch: BatchStats::new(b, b * 64),
            },
            latency_s: t0.elapsed().as_secs_f64(),
        });
    }
    let fitted = calibrate(&model, &HardwareProfile::cpu_tiny(), &samples, 10);
    Ok((PerfModel::new(model, fitted), samples))
}

fn tiny_model_spec(rt: &Runtime) -> crate::config::ModelSpec {
    let m = &rt.manifest;
    crate::config::ModelSpec {
        name: "tiny".into(),
        layers: m.layers,
        hidden: m.hidden,
        q_heads: m.q_heads,
        kv_heads: m.kv_heads,
        head_dim: m.head_dim,
        ffn: m.ffn,
        vocab: m.vocab,
        bytes_per_value: 4.0,
        tensor_parallel: 1,
    }
}

/// Serve a trace end-to-end with real model execution.
pub fn serve_trace(
    artifacts_dir: &Path,
    trace: &Trace,
    cfg: &EngineConfig,
) -> Result<EngineOutcome> {
    let rt = Runtime::load(artifacts_dir)?;
    serve_trace_with_runtime(&rt, trace, cfg)
}

pub fn serve_trace_with_runtime(
    rt: &Runtime,
    trace: &Trace,
    cfg: &EngineConfig,
) -> Result<EngineOutcome> {
    let (pm, mut samples) = calibrate_runtime(rt)?;
    let smax = rt.manifest.smax;
    let vocab = rt.manifest.vocab;
    let kv_elems = rt.kv_elems();
    let max_batch = rt.max_decode_batch();

    // Feeder thread replays arrivals in compressed wall-clock time.
    let (tx, rx) = mpsc::channel::<Request>();
    let feed: Vec<Request> = trace.requests.clone();
    let scale = cfg.time_scale.max(1e-9);
    let feeder = std::thread::spawn(move || {
        let start = Instant::now();
        for r in feed {
            let due = r.arrival / scale;
            let now = start.elapsed().as_secs_f64();
            if due > now {
                std::thread::sleep(std::time::Duration::from_secs_f64(due - now));
            }
            if tx.send(r).is_err() {
                return;
            }
        }
    });

    let start = Instant::now();
    let mut rng = Pcg::new(cfg.seed, 616);
    let mut online_q: VecDeque<Request> = VecDeque::new();
    let mut offline_q: VecDeque<Request> = VecDeque::new();
    let mut strict_online: Vec<Live> = Vec::new();
    let mut strict_offline: Vec<Live> = Vec::new();
    let mut relaxed_offline: Vec<Live> = Vec::new();
    let mut recorder = Recorder::new();
    let mut feeding = true;

    let mut prefills = 0u64;
    let mut strict_steps = 0u64;
    let mut relaxed_steps = 0u64;
    let mut online_tokens = 0u64;
    let mut offline_tokens = 0u64;

    // Scale SLO to compressed time so violation semantics match the trace.
    let slo_tpot = cfg.slo.tpot;

    let now_s = |start: &Instant| start.elapsed().as_secs_f64();

    loop {
        // ---- intake ----
        loop {
            match rx.try_recv() {
                Ok(r) => {
                    if r.class == Class::Online || cfg.policy == Policy::BasePd {
                        online_q.push_back(r);
                    } else {
                        offline_q.push_back(r);
                    }
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    feeding = false;
                    break;
                }
            }
        }

        let idle = online_q.is_empty()
            && offline_q.is_empty()
            && strict_online.is_empty()
            && strict_offline.is_empty()
            && relaxed_offline.is_empty();
        if idle {
            if !feeding {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
            continue;
        }

        // ---- relaxed pool: online prefill first (priority), else offline ----
        let next_prefill = if let Some(r) = online_q.pop_front() {
            Some(r)
        } else if strict_online.is_empty() || !cfg.policy.offline_idle_only() {
            // Offline prefill only when the online side is not starved for
            // compute (single-CPU analog of "idle-only").
            offline_q.pop_front()
        } else {
            None
        };
        if let Some(mut req) = next_prefill {
            let plen = req.prompt_len.min(smax - cfg.max_output.max(1) - 1).max(1);
            req.prompt_len = plen;
            req.output_len = req.output_len.min(cfg.max_output).max(1);
            let toks: Vec<i32> =
                (0..plen).map(|_| rng.below(vocab) as i32).collect();
            let t0 = Instant::now();
            let out = rt.prefill(&toks)?;
            let lat = t0.elapsed().as_secs_f64();
            samples.push(Sample {
                kind: SampleKind::Prefill { prompt_len: plen },
                latency_s: lat,
            });
            prefills += 1;
            req.mark_first_token(now_s(&start) * scale);
            if req.class == Class::Online {
                online_tokens += 1;
            } else {
                offline_tokens += 1;
            }
            let last = argmax(&out.logits);
            let live = Live {
                position: plen as i32,
                tokens: toks,
                kv: out.kv,
                last_token: last,
                req,
            };
            if live.req.is_finished() {
                let mut r = live.req;
                r.finished_at = Some(now_s(&start) * scale);
                recorder.record(&r);
            } else if live.req.class == Class::Online
                || cfg.policy == Policy::BasePd
            {
                strict_online.push(live);
            } else if cfg.policy.offline_decode_on_relaxed() {
                relaxed_offline.push(live);
            } else {
                strict_offline.push(live);
            }
        }

        // ---- strict pool: mix decoding selection + one real step ----
        if !strict_online.is_empty() || !strict_offline.is_empty() {
            let online_c: Vec<Candidate> = strict_online
                .iter()
                .enumerate()
                .map(|(i, l)| (i as u64, l.position as usize))
                .collect();
            let offline_c: Vec<Candidate> = strict_offline
                .iter()
                .enumerate()
                .map(|(i, l)| (i as u64, l.position as usize))
                .collect();
            let chosen_off: Vec<usize> = if cfg.policy.slo_aware_mix_decode() {
                let sel = select_decode_batch(
                    &pm,
                    &online_c,
                    &offline_c,
                    slo_tpot,
                    cfg.sched.mix_probe_iters,
                    &mut rng,
                );
                sel.offline.iter().map(|&i| i as usize).collect()
            } else {
                // Baselines: offline up to the cap / everything for BasePd.
                let cap = cfg
                    .policy
                    .static_offline_decode_cap(cfg.sched.baseline_decode_cap)
                    .unwrap_or(usize::MAX);
                let room = cap.saturating_sub(strict_online.len());
                (0..strict_offline.len().min(room)).collect()
            };
            // Respect the runtime's largest decode bucket.
            let n_on = strict_online.len().min(max_batch);
            let n_off = chosen_off.len().min(max_batch - n_on.min(max_batch));
            let mut stats = BatchStats::empty();
            let mut entries: Vec<DecodeEntry> = Vec::with_capacity(n_on + n_off);
            // Split borrows: online first, then chosen offline.
            let (on_slice, off_slice) =
                (&mut strict_online[..], &mut strict_offline[..]);
            for l in on_slice.iter_mut().take(n_on) {
                stats = stats.with(l.position as usize);
                entries.push(DecodeEntry {
                    token: l.last_token,
                    position: l.position,
                    kv: &mut l.kv,
                });
            }
            let mut picked = 0usize;
            for (i, l) in off_slice.iter_mut().enumerate() {
                if picked >= n_off {
                    break;
                }
                if chosen_off.contains(&i) {
                    stats = stats.with(l.position as usize);
                    entries.push(DecodeEntry {
                        token: l.last_token,
                        position: l.position,
                        kv: &mut l.kv,
                    });
                    picked += 1;
                }
            }
            if !entries.is_empty() {
                let t0 = Instant::now();
                let logits = rt.decode(&mut entries)?;
                let lat = t0.elapsed().as_secs_f64();
                samples.push(Sample {
                    kind: SampleKind::Decode { batch: stats },
                    latency_s: lat,
                });
                strict_steps += 1;
                drop(entries);
                let now = now_s(&start) * scale;
                credit_tokens(
                    &mut strict_online,
                    &logits[..n_on],
                    now,
                    smax,
                    &mut recorder,
                    &mut online_tokens,
                );
                let off_logits = &logits[n_on..];
                credit_chosen(
                    &mut strict_offline,
                    &chosen_off[..picked],
                    off_logits,
                    now,
                    smax,
                    &mut recorder,
                    &mut offline_tokens,
                );
            }
        }

        // ---- relaxed pool: offline decode (OOCO flexibility) ----
        if cfg.policy.offline_decode_on_relaxed() && !relaxed_offline.is_empty() {
            let n = relaxed_offline.len().min(max_batch);
            let mut stats = BatchStats::empty();
            let mut entries: Vec<DecodeEntry> = Vec::with_capacity(n);
            for l in relaxed_offline.iter_mut().take(n) {
                stats = stats.with(l.position as usize);
                entries.push(DecodeEntry {
                    token: l.last_token,
                    position: l.position,
                    kv: &mut l.kv,
                });
            }
            let t0 = Instant::now();
            let logits = rt.decode(&mut entries)?;
            samples.push(Sample {
                kind: SampleKind::Decode { batch: stats },
                latency_s: t0.elapsed().as_secs_f64(),
            });
            relaxed_steps += 1;
            drop(entries);
            let now = now_s(&start) * scale;
            credit_tokens(
                &mut relaxed_offline,
                &logits[..n],
                now,
                smax,
                &mut recorder,
                &mut offline_tokens,
            );
        }

        let _ = kv_elems;
    }

    feeder.join().ok();
    let wall = start.elapsed().as_secs_f64();
    let duration = trace.duration().max(1e-9);
    let report = recorder.report(&cfg.slo, duration);
    Ok(EngineOutcome {
        report,
        wall_s: wall,
        prefills,
        strict_steps,
        relaxed_steps,
        online_tokens,
        offline_tokens,
        samples,
        perf_model: pm,
    })
}

fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// Credit one generated token to the first `logits.len()` entries of `pool`;
/// retire finished (or KV-exhausted) stepped requests, recording them.
fn credit_tokens(
    pool: &mut Vec<Live>,
    logits: &[Vec<f32>],
    now: f64,
    smax: usize,
    recorder: &mut Recorder,
    token_counter: &mut u64,
) {
    let stepped = logits.len();
    for (i, lg) in logits.iter().enumerate() {
        let l = &mut pool[i];
        l.last_token = argmax(lg);
        l.position += 1;
        *token_counter += 1;
        l.req.mark_token(now);
    }
    let mut keep = Vec::with_capacity(pool.len());
    for (i, mut l) in pool.drain(..).enumerate() {
        let done = i < stepped
            && (l.req.is_finished() || l.position as usize >= smax - 1);
        if done {
            l.req.finished_at.get_or_insert(now);
            recorder.record(&l.req);
        } else {
            keep.push(l);
        }
    }
    *pool = keep;
}

/// Same, but for the subset of `pool` indices in `chosen` (offline mix-in).
fn credit_chosen(
    pool: &mut Vec<Live>,
    chosen: &[usize],
    logits: &[Vec<f32>],
    now: f64,
    smax: usize,
    recorder: &mut Recorder,
    token_counter: &mut u64,
) {
    let mut stepped = vec![false; pool.len()];
    for (j, &idx) in chosen.iter().enumerate() {
        if j >= logits.len() {
            break;
        }
        stepped[idx] = true;
        let l = &mut pool[idx];
        l.last_token = argmax(&logits[j]);
        l.position += 1;
        *token_counter += 1;
        l.req.mark_token(now);
    }
    let mut keep = Vec::with_capacity(pool.len());
    for (i, mut l) in pool.drain(..).enumerate() {
        let done = stepped[i]
            && (l.req.is_finished() || l.position as usize >= smax - 1);
        if done {
            l.req.finished_at.get_or_insert(now);
            recorder.record(&l.req);
        } else {
            keep.push(l);
        }
    }
    *pool = keep;
}
