//! Leveled stderr logger with a global verbosity switch.
//!
//! Deliberately tiny: the serving hot path must not allocate or lock for
//! suppressed levels, so the level check is a single relaxed atomic load.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn set_level_from_str(s: &str) {
    let level = match s.to_ascii_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "info" => Level::Info,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    };
    set_level(level);
}

#[inline]
pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {module}: {msg}");
    }
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn level_from_str() {
        set_level_from_str("debug");
        assert!(enabled(Level::Debug));
        set_level_from_str("bogus"); // falls back to info
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
