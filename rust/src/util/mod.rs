//! Substrate utilities: JSON, RNG, statistics, CLI parsing, logging.
//!
//! These exist because the offline vendor set has no serde/clap/rand/criterion;
//! each is a small, fully-tested replacement scoped to what OOCO needs.

pub mod cli;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
