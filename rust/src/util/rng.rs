//! Deterministic PRNG + distribution sampling.
//!
//! The `rand` crate is not in the offline vendor set; this is a PCG-XSH-RR
//! 64/32 generator (O'Neill 2014) with the handful of distributions the
//! trace generators and schedulers need. Everything in the repo that needs
//! randomness takes an explicit seed so experiments replay bit-identically.

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, period 2^64 per stream.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Seed with an arbitrary value; `stream` selects an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience single-stream constructor.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Unbiased via rejection (Lemire-style).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with the given rate (mean 1/rate) — Poisson inter-arrivals.
    pub fn exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        // 1 - f64() is in (0, 1], so ln() is finite.
        -(1.0 - self.f64()).ln() / rate
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal parameterized by the *target arithmetic mean* and the
    /// underlying normal sigma — request-length sampling wants to hit the
    /// published dataset means (Table 5) exactly in expectation.
    pub fn lognormal_mean(&mut self, mean: f64, sigma: f64) -> f64 {
        assert!(mean > 0.0);
        let mu = mean.ln() - sigma * sigma / 2.0;
        (mu + sigma * self.normal()).exp()
    }

    /// Poisson-distributed count (Knuth for small lambda, normal approx above).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let v = lambda + lambda.sqrt() * self.normal();
            return v.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.below(i + 1);
            slice.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from 0..n (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg::seeded(42);
        let mut b = Pcg::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg::seeded(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg::new(1, 10);
        let mut b = Pcg::new(1, 11);
        let av: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let bv: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(av, bv);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg::seeded(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_covers_all_and_in_range() {
        let mut r = Pcg::seeded(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Pcg::seeded(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::seeded(13);
        let n = 50_000;
        let vals: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = vals.iter().sum::<f64>() / n as f64;
        let var = vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_hits_target_mean() {
        let mut r = Pcg::seeded(17);
        let n = 100_000;
        let target = 1892.47; // OOC online prompt mean, Table 5
        let mean: f64 =
            (0..n).map(|_| r.lognormal_mean(target, 0.8)).sum::<f64>() / n as f64;
        assert!((mean / target - 1.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Pcg::seeded(19);
        for lambda in [0.5, 5.0, 80.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean / lambda - 1.0).abs() < 0.07,
                "lambda {lambda} mean {mean}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::seeded(23);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg::seeded(29);
        let s = r.sample_indices(20, 8);
        assert_eq!(s.len(), 8);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 8);
        // k > n clamps
        assert_eq!(r.sample_indices(3, 10).len(), 3);
    }
}
