//! Small statistics helpers: summaries, percentiles, online accumulators.

/// Summary statistics over a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn empty() -> Self {
        Summary {
            count: 0,
            mean: 0.0,
            std: 0.0,
            min: 0.0,
            max: 0.0,
            p50: 0.0,
            p90: 0.0,
            p99: 0.0,
        }
    }

    /// Machine-readable form (`util::json`), for cross-run comparisons.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("mean", Json::Num(self.mean)),
            ("std", Json::Num(self.std)),
            ("min", Json::Num(self.min)),
            ("max", Json::Num(self.max)),
            ("p50", Json::Num(self.p50)),
            ("p90", Json::Num(self.p90)),
            ("p99", Json::Num(self.p99)),
        ])
    }

    pub fn of(values: &[f64]) -> Self {
        if values.is_empty() {
            return Self::empty();
        }
        let mut sorted = values.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let count = sorted.len();
        let mean = sorted.iter().sum::<f64>() / count as f64;
        let var =
            sorted.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / count as f64;
        Summary {
            count,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[count - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, `p` in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Percentile of an unsorted slice (copies + sorts).
pub fn percentile(values: &[f64], p: f64) -> f64 {
    let mut sorted = values.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, p)
}

/// Streaming mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: usize,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

// ------------------------------------------------------ streaming summary

/// Smallest representable latency (s): everything at or below lands in the
/// underflow bucket and is represented as `LS_MIN`.
const LS_MIN: f64 = 1e-7;
/// Upper edge of the bucketed range (s); larger values clamp to the last
/// bucket (exact `min`/`max` are still tracked separately).
const LS_MAX: f64 = 1e6;
/// Geometric bucket growth factor: ~4% relative bucket width, so streamed
/// percentiles sit within one bucket (≤4%) of the exact-sort values.
const LS_GROWTH: f64 = 1.04;

/// Streaming latency accumulator: exact count/mean/std/min/max (Welford)
/// plus log-bucketed counts for percentiles in O(buckets) memory — the
/// replacement for collecting `Vec<f64>` and sorting at end of run
/// (DESIGN.md §3.10). Buckets span [`LS_MIN`, `LS_MAX`) at [`LS_GROWTH`]
/// relative width; quantiles return the geometric bucket midpoint clamped
/// to the exact observed [min, max].
#[derive(Debug, Clone)]
pub struct LatencySummary {
    counts: Vec<u64>,
    n: usize,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for LatencySummary {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencySummary {
    /// Number of log buckets (plus one underflow bucket at index 0).
    fn buckets() -> usize {
        ((LS_MAX / LS_MIN).ln() / LS_GROWTH.ln()).ceil() as usize + 2
    }

    pub fn new() -> Self {
        LatencySummary {
            counts: vec![0; Self::buckets()],
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// One-bucket relative width — the accuracy bound on quantiles.
    pub fn bucket_relative_width() -> f64 {
        LS_GROWTH - 1.0
    }

    fn bucket_of(x: f64) -> usize {
        if x.is_nan() || x <= LS_MIN {
            return 0; // underflow (incl. zero and negatives)
        }
        let idx = ((x / LS_MIN).ln() / LS_GROWTH.ln()).floor() as usize + 1;
        idx.min(Self::buckets() - 1)
    }

    /// Geometric midpoint of bucket `i` (the quantile representative).
    fn bucket_mid(i: usize) -> f64 {
        if i == 0 {
            return LS_MIN;
        }
        LS_MIN * LS_GROWTH.powf(i as f64 - 0.5)
    }

    pub fn record(&mut self, x: f64) {
        self.counts[Self::bucket_of(x)] += 1;
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Build from any stream of samples — the shared constructor behind
    /// every report's percentile summary.
    pub fn from_stream<I: IntoIterator<Item = f64>>(xs: I) -> Self {
        let mut s = Self::new();
        for x in xs {
            s.record(x);
        }
        s
    }

    pub fn merge(&mut self, other: &Self) {
        if other.n == 0 {
            return;
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        // Chan et al. parallel mean/M2 combination.
        let n = (self.n + other.n) as f64;
        let delta = other.mean - self.mean;
        self.mean += delta * other.n as f64 / n;
        self.m2 += other.m2
            + delta * delta * (self.n as f64) * (other.n as f64) / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    /// Quantile estimate, `p` in [0, 100]: the geometric midpoint of the
    /// bucket holding the rank, clamped to the exact observed range.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.n as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // Underflow bucket: report the exact observed minimum
                // rather than a synthetic sub-LS_MIN representative.
                let v = if i == 0 { self.min } else { Self::bucket_mid(i) };
                return v.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Snapshot in the report-facing [`Summary`] shape.
    pub fn summary(&self) -> Summary {
        if self.n == 0 {
            return Summary::empty();
        }
        let var = if self.n < 2 { 0.0 } else { self.m2 / self.n as f64 };
        Summary {
            count: self.n,
            mean: self.mean,
            std: var.max(0.0).sqrt(),
            min: self.min,
            max: self.max,
            p50: self.quantile(50.0),
            p90: self.quantile(90.0),
            p99: self.quantile(99.0),
        }
    }
}

/// Fixed-bucket histogram over [lo, hi); values outside clamp to end buckets.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, buckets: usize) -> Self {
        assert!(hi > lo && buckets > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; buckets],
        }
    }

    pub fn push(&mut self, x: f64) {
        let n = self.counts.len();
        let t = ((x - self.lo) / (self.hi - self.lo) * n as f64) as i64;
        let idx = t.clamp(0, n as i64 - 1) as usize;
        self.counts[idx] += 1;
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Bucket midpoints (for printing series).
    pub fn midpoints(&self) -> Vec<f64> {
        let n = self.counts.len();
        let w = (self.hi - self.lo) / n as f64;
        (0..n).map(|i| self.lo + (i as f64 + 0.5) * w).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_and_single() {
        assert_eq!(Summary::of(&[]).count, 0);
        let s = Summary::of(&[7.5]);
        assert_eq!(s.p99, 7.5);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile(&v, 0.0) - 10.0).abs() < 1e-12);
        assert!((percentile(&v, 100.0) - 40.0).abs() < 1e-12);
        assert!((percentile(&v, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn online_matches_batch() {
        let vals: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 10.0).collect();
        let mut o = Online::new();
        for &v in &vals {
            o.push(v);
        }
        let s = Summary::of(&vals);
        assert!((o.mean() - s.mean).abs() < 1e-9);
        assert!((o.std() - s.std).abs() < 1e-9);
        assert_eq!(o.min(), s.min);
        assert_eq!(o.max(), s.max);
    }

    #[test]
    fn latency_summary_tracks_exact_moments() {
        let vals: Vec<f64> =
            (0..1000).map(|i| 0.001 + (i as f64).sin().abs() * 5.0).collect();
        let s = LatencySummary::from_stream(vals.iter().copied());
        let exact = Summary::of(&vals);
        assert_eq!(s.count(), exact.count);
        let snap = s.summary();
        assert!((snap.mean - exact.mean).abs() < 1e-9);
        assert!((snap.std - exact.std).abs() < 1e-9);
        assert_eq!(snap.min, exact.min);
        assert_eq!(snap.max, exact.max);
    }

    #[test]
    fn latency_summary_quantiles_within_one_bucket() {
        // Log-uniform spread over 5 decades — the adversarial case for a
        // fixed-range histogram, the design case for a log-bucketed one.
        let vals: Vec<f64> = (0..5000)
            .map(|i| 1e-4 * 10f64.powf(5.0 * (i as f64) / 5000.0))
            .collect();
        let s = LatencySummary::from_stream(vals.iter().copied());
        let tol = LatencySummary::bucket_relative_width();
        for p in [50.0, 90.0, 99.0] {
            let exact = percentile(&vals, p);
            let est = s.quantile(p);
            assert!(
                (est - exact).abs() <= exact * tol,
                "p{p}: est {est} vs exact {exact} (tol {tol})"
            );
        }
    }

    #[test]
    fn latency_summary_degenerate_cases() {
        assert_eq!(LatencySummary::new().summary(), Summary::empty());
        // A single sample is reported exactly (clamped to min == max).
        let s = LatencySummary::from_stream([1.0]);
        let snap = s.summary();
        assert_eq!(snap.p50, 1.0);
        assert_eq!(snap.p99, 1.0);
        assert_eq!(snap.std, 0.0);
        // Zero and negative samples land in the underflow bucket but keep
        // exact min/max.
        let s = LatencySummary::from_stream([0.0, 0.0, 2.0]);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 2.0);
        assert_eq!(s.quantile(50.0), 0.0);
    }

    #[test]
    fn latency_summary_merge_matches_single_pass() {
        let a_vals: Vec<f64> = (0..300).map(|i| 0.01 * (i + 1) as f64).collect();
        let b_vals: Vec<f64> = (0..500).map(|i| 0.5 + 0.002 * i as f64).collect();
        let mut a = LatencySummary::from_stream(a_vals.iter().copied());
        let b = LatencySummary::from_stream(b_vals.iter().copied());
        a.merge(&b);
        let mut all = a_vals.clone();
        all.extend(&b_vals);
        let joint = LatencySummary::from_stream(all.iter().copied());
        assert_eq!(a.count(), joint.count());
        assert!((a.mean() - joint.mean()).abs() < 1e-9);
        assert_eq!(a.summary().p90, joint.summary().p90);
    }

    #[test]
    fn histogram_clamps_and_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push(-5.0); // clamps to bucket 0
        h.push(0.5);
        h.push(9.9);
        h.push(100.0); // clamps to last
        assert_eq!(h.total(), 4);
        assert_eq!(h.counts()[0], 2);
        assert_eq!(h.counts()[9], 2);
        assert_eq!(h.midpoints().len(), 10);
        assert!((h.midpoints()[0] - 0.5).abs() < 1e-12);
    }
}
