//! Minimal JSON parser and writer.
//!
//! `serde`/`serde_json` are not available in the offline vendor set, so the
//! config system, trace files and the artifacts manifest are read through
//! this self-contained implementation. It supports the full JSON grammar
//! (objects, arrays, strings with escapes, numbers, bools, null) plus the
//! accessor helpers the rest of the crate uses.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept sorted (BTreeMap) so emitted
/// JSON is deterministic — handy for golden tests and diffable outputs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for debuggability.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl Json {
    // ----------------------------------------------------------- accessors

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| {
            if n.fract() == 0.0 && n.abs() <= i64::MAX as f64 {
                Some(n as i64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for anything that isn't there.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup; `Json::Null` when out of bounds.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Required-field helpers that produce good error messages.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("missing/non-numeric field `{key}`"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("missing/non-integer field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("missing/non-string field `{key}`"))
    }

    // --------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Insert/replace `key` on an object; no-op on non-objects. Lets
    /// callers layer keys (e.g. the `meta` header) onto a composed report.
    pub fn set(&mut self, key: &str, v: Json) {
        if let Json::Obj(o) = self {
            o.insert(key.to_string(), v);
        }
    }

    /// Remove `key` from an object, returning it. Used by tests that
    /// compare reports modulo non-deterministic keys.
    pub fn remove(&mut self, key: &str) -> Option<Json> {
        match self {
            Json::Obj(o) => o.remove(key),
            _ => None,
        }
    }

    pub fn arr_f64(values: &[f64]) -> Json {
        Json::Arr(values.iter().map(|v| Json::Num(*v)).collect())
    }

    pub fn arr_usize(values: &[usize]) -> Json {
        Json::Arr(values.iter().map(|v| Json::Num(*v as f64)).collect())
    }

    // -------------------------------------------------------------- parsing

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?)
    }

    // -------------------------------------------------------------- writing

    /// Compact single-line serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling for completeness.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(ch.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").idx(0).as_f64(), Some(1.0));
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A 😀"));
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = Json::parse("\"héllo wörld 中文\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo wörld 中文"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,true,null],"obj":{"k":"v"},"s":"a\"b"}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn accessors_are_total() {
        let v = Json::parse("{\"a\": 1}").unwrap();
        assert_eq!(v.get("missing").get("deeper").idx(3).as_f64(), None);
        assert!(v.req_f64("missing").is_err());
        assert_eq!(v.req_f64("a").unwrap(), 1.0);
    }

    #[test]
    fn u64_bounds() {
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap().to_string(), "[]");
        assert_eq!(Json::parse("{}").unwrap().to_pretty(), "{}");
    }

    #[test]
    fn manifest_like_document() {
        let text = r#"{
            "format": "hlo-text",
            "prefill_buckets": [64, 128, 256, 384],
            "weights": [{"name": "['embed']", "shape": [512, 256], "offset_bytes": 0}]
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("prefill_buckets").as_arr().unwrap().len(), 4);
        assert_eq!(v.get("weights").idx(0).get("shape").idx(1).as_usize(), Some(256));
    }
}
