//! Tiny flag-style argument parser (clap is not in the offline vendor set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Typed getters with defaults keep call sites terse:
//!
//! ```ignore
//! let args = Args::parse_env();
//! let qps = args.f64("offline-qps", 2.0);
//! let policy = args.str("policy", "ooco");
//! ```

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn parse_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(body) = item.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(item);
            }
        }
        out
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flags.get(key).map(|s| s.as_str()).unwrap_or(default)
    }

    pub fn opt_str(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Parse a flag through [`std::str::FromStr`] (the idiomatic path for
    /// domain types like `Policy`, `Ablation`, `OverloadMode`). Absent flags
    /// yield `default`; present-but-invalid values surface the type's parse
    /// error instead of being silently defaulted.
    pub fn parse_flag<T>(&self, key: &str, default: T) -> anyhow::Result<T>
    where
        T: std::str::FromStr,
        T::Err: Into<anyhow::Error>,
    {
        match self.flags.get(key) {
            Some(s) => s.parse::<T>().map_err(Into::into),
            None => Ok(default),
        }
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        match self.flags.get(key).map(|s| s.as_str()) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => default,
            None => default,
        }
    }

    /// Comma-separated list of f64 values.
    pub fn f64_list(&self, key: &str, default: &[f64]) -> Vec<f64> {
        match self.flags.get(key) {
            Some(s) => s
                .split(',')
                .filter_map(|t| t.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }

    /// Comma-separated list of strings.
    pub fn str_list(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.flags.get(key) {
            Some(s) => s.split(',').map(|t| t.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(items: &[&str]) -> Args {
        Args::parse(items.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_forms() {
        // Note: a bare `--flag` immediately followed by a positional would
        // consume it as a value — use `--flag=true` in that position.
        let a = parse(&["--x", "1.5", "--y=hello", "pos1", "pos2", "--flag"]);
        assert_eq!(a.f64("x", 0.0), 1.5);
        assert_eq!(a.str("y", ""), "hello");
        assert!(a.bool("flag", false));
        assert_eq!(a.positional(), &["pos1", "pos2"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.f64("missing", 3.25), 3.25);
        assert_eq!(a.usize("missing", 7), 7);
        assert_eq!(a.str("missing", "d"), "d");
        assert!(!a.bool("missing", false));
    }

    #[test]
    fn bool_spellings() {
        assert!(parse(&["--a", "yes"]).bool("a", false));
        assert!(!parse(&["--a", "no"]).bool("a", true));
        assert!(parse(&["--a=1"]).bool("a", false));
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["--verbose"]);
        assert!(a.bool("verbose", false));
    }

    #[test]
    fn lists() {
        let a = parse(&["--qps", "0.5, 1, 2.5", "--names=a,b"]);
        assert_eq!(a.f64_list("qps", &[]), vec![0.5, 1.0, 2.5]);
        assert_eq!(a.str_list("names", &[]), vec!["a", "b"]);
        assert_eq!(a.f64_list("missing", &[9.0]), vec![9.0]);
    }

    #[test]
    fn parse_flag_via_fromstr() {
        use crate::coordinator::{OverloadMode, Policy};
        let a = parse(&["--policy", "base-pd", "--overload", "nonsense"]);
        let p: Policy = a.parse_flag("policy", Policy::Ooco).unwrap();
        assert_eq!(p, Policy::BasePd);
        // Absent flag -> default.
        let d: Policy = a.parse_flag("missing", Policy::Ooco).unwrap();
        assert_eq!(d, Policy::Ooco);
        // Present but invalid -> error, not silent default.
        assert!(a
            .parse_flag("overload", OverloadMode::BestEffort)
            .is_err());
    }

    #[test]
    fn negative_numbers_as_values() {
        // "--x -3" would treat -3 as a value because it doesn't start with --
        let a = parse(&["--x", "-3"]);
        assert_eq!(a.f64("x", 0.0), -3.0);
    }
}
