//! Serving metrics: TTFT/TPOT, SLO violation accounting, throughput,
//! KV-transport accounting, and elastic-pool accounting.
//!
//! `Recorder` ingests finished requests (from the simulator or the real
//! engine) and produces the quantities the paper's evaluation reports:
//! online SLO violation rate (§5.2's 3% threshold), offline token
//! throughput, and latency percentiles. [`TransportReport`] aggregates the
//! transport subsystem's link utilization, transfer stall time, and the
//! recoverable fast-preemption statistics (preemption-to-restart latency).
//! [`PoolReport`] tracks the elastic pool manager (DESIGN.md §3.6):
//! per-epoch pool sizes, role-transition durations, and stranded capacity.
//! Every report has a `to_json` form (`util::json`) so experiments are
//! comparable across runs by machine.

use crate::config::SloSpec;
use crate::request::{Class, Request};
use crate::util::json::Json;
use crate::util::stats::{LatencySummary, Summary};

/// Per-link transport accounting over one run.
#[derive(Debug, Clone)]
pub struct LinkReport {
    pub name: String,
    /// Bytes of completed (non-cancelled) chunks.
    pub bytes_moved: f64,
    /// Seconds the medium spent serving chunks.
    pub busy_s: f64,
    /// `busy_s` over the observation window.
    pub utilization: f64,
    pub jobs_completed: u64,
    /// Queueing/contention delay added on top of contention-free transfer
    /// time, summed over completed jobs.
    pub stall_s: f64,
}

/// KV-transport subsystem metrics (modeled interconnect + recoverable fast
/// preemption — DESIGN.md §3.5).
#[derive(Debug, Clone)]
pub struct TransportReport {
    pub links: Vec<LinkReport>,
    /// Total transfer stall across all links (s).
    pub stall_s: f64,
    /// Strict evictions recovered by streaming KV into the relaxed pool.
    pub rescues: u64,
    /// Evictions recovered via the host staging buffer.
    pub offloads: u64,
    /// Staged caches streamed back onto a relaxed instance.
    pub restores: u64,
    /// Eviction-to-decode-resume latency of recovered evictions.
    pub restart_latency: Summary,
    pub bytes_enqueued: f64,
    pub bytes_delivered: f64,
    pub jobs_cancelled: u64,
}

impl TransportReport {
    /// One-line summary for bench output.
    pub fn summary_line(&self) -> String {
        let links: Vec<String> = self
            .links
            .iter()
            .map(|l| {
                format!(
                    "{} {:.1} MB util {:.1}% stall {:.2}s",
                    l.name,
                    l.bytes_moved / 1e6,
                    l.utilization * 100.0,
                    l.stall_s
                )
            })
            .collect();
        format!(
            "transport: {} | rescues {} offloads {} restores {} cancelled {} | restart p50 {:.3}s p99 {:.3}s",
            links.join(" | "),
            self.rescues,
            self.offloads,
            self.restores,
            self.jobs_cancelled,
            self.restart_latency.p50,
            self.restart_latency.p99,
        )
    }

    pub fn to_json(&self) -> Json {
        let links: Vec<Json> = self
            .links
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("name", Json::Str(l.name.clone())),
                    ("bytes_moved", Json::Num(l.bytes_moved)),
                    ("busy_s", Json::Num(l.busy_s)),
                    ("utilization", Json::Num(l.utilization)),
                    ("jobs_completed", Json::Num(l.jobs_completed as f64)),
                    ("stall_s", Json::Num(l.stall_s)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("links", Json::Arr(links)),
            ("stall_s", Json::Num(self.stall_s)),
            ("rescues", Json::Num(self.rescues as f64)),
            ("offloads", Json::Num(self.offloads as f64)),
            ("restores", Json::Num(self.restores as f64)),
            ("restart_latency", self.restart_latency.to_json()),
            ("bytes_enqueued", Json::Num(self.bytes_enqueued)),
            ("bytes_delivered", Json::Num(self.bytes_delivered)),
            ("jobs_cancelled", Json::Num(self.jobs_cancelled as f64)),
        ])
    }
}

/// One repartition decision of the elastic pool manager.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PoolEpoch {
    /// Plan time (virtual or wall seconds).
    pub at: f64,
    /// Pool sizes when the plan was computed.
    pub relaxed: usize,
    pub strict: usize,
    /// Strict-pool size the planner asked for.
    pub planned_strict: usize,
    /// Burst-corrected arrival-rate estimates the plan was computed from
    /// (req/s, by *scheduled* class) — the load context for the decision.
    pub est_online_rate: f64,
    pub est_offline_rate: f64,
}

/// Elastic pool-manager metrics over one run (DESIGN.md §3.6).
#[derive(Debug, Clone)]
pub struct PoolReport {
    /// `PoolPolicy` display form.
    pub policy: String,
    /// Repartition plans computed (`RepartitionPlan` actions).
    pub plans: u64,
    /// Completed role flips (drain → flip → warm cycles).
    pub flips: u64,
    /// Per-plan pool sizes (the plan timeline).
    pub epochs: Vec<PoolEpoch>,
    /// Drain-start to warm-end durations of completed transitions (s).
    pub transition_s: Summary,
    /// Instance-seconds spent away from the planned split — the capacity
    /// stranded on the wrong side of the pool boundary, integrated as
    /// `|strict_actual - strict_planned| · dt` over the run.
    pub stranded_instance_s: f64,
    /// Pool sizes at the end of the run.
    pub final_relaxed: usize,
    pub final_strict: usize,
}

impl PoolReport {
    /// One-line summary for bench output.
    pub fn summary_line(&self) -> String {
        let (min_s, max_s) = self.epochs.iter().fold(
            (self.final_strict, self.final_strict),
            |(lo, hi), e| (lo.min(e.strict), hi.max(e.strict)),
        );
        format!(
            "pool[{}]: plans {} flips {} | strict {}..{} (end {}r/{}s) | transition p50 {:.2}s max {:.2}s | stranded {:.1} inst·s",
            self.policy,
            self.plans,
            self.flips,
            min_s,
            max_s,
            self.final_relaxed,
            self.final_strict,
            self.transition_s.p50,
            self.transition_s.max,
            self.stranded_instance_s,
        )
    }

    pub fn to_json(&self) -> Json {
        let epochs: Vec<Json> = self
            .epochs
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("at", Json::Num(e.at)),
                    ("relaxed", Json::Num(e.relaxed as f64)),
                    ("strict", Json::Num(e.strict as f64)),
                    ("planned_strict", Json::Num(e.planned_strict as f64)),
                    ("est_online_rate", Json::Num(e.est_online_rate)),
                    ("est_offline_rate", Json::Num(e.est_offline_rate)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("policy", Json::Str(self.policy.clone())),
            ("plans", Json::Num(self.plans as f64)),
            ("flips", Json::Num(self.flips as f64)),
            ("epochs", Json::Arr(epochs)),
            ("transition_s", self.transition_s.to_json()),
            ("stranded_instance_s", Json::Num(self.stranded_instance_s)),
            ("final_relaxed", Json::Num(self.final_relaxed as f64)),
            ("final_strict", Json::Num(self.final_strict as f64)),
        ])
    }
}

/// Prefix-sharing KV cache metrics over one run (DESIGN.md §3.7).
#[derive(Debug, Clone)]
pub struct PrefixReport {
    pub enabled: bool,
    /// Cache resolutions at prefill admission (declared-prefix requests).
    pub lookups: u64,
    /// Resolutions matching at least one cached block.
    pub hits: u64,
    /// Token-weighted hit rate: prompt tokens served from cache over all
    /// prompt tokens admitted to prefill.
    pub hit_rate: f64,
    /// Prompt tokens whose prefill recompute was skipped.
    pub prefill_tokens_saved: u64,
    /// Per-scheduled-class breakdown of the saving.
    pub online_tokens_saved: u64,
    pub offline_tokens_saved: u64,
    /// KV tokens not moved by dispatch/migration/rescue/restore because
    /// the destination already held the blocks.
    pub transfer_tokens_saved: u64,
    /// Copy-on-write block copies (partial-block divergence).
    pub cow_copies: u64,
    /// Reclaimable cache blocks evicted (LRU reclaim + drain purges).
    pub evicted_blocks: u64,
    /// Time-integral of reclaimable cached blocks (block·s): capacity held
    /// as cache while remaining admittable.
    pub reclaimed_block_s: f64,
    /// Reclaimable cache blocks at the end of the run.
    pub cached_blocks_final: usize,
}

impl PrefixReport {
    /// One-line summary for bench output.
    pub fn summary_line(&self) -> String {
        if !self.enabled {
            return "prefix: disabled".into();
        }
        format!(
            "prefix: hit {:.1}% ({}/{} lookups) | saved {} prefill tok ({} online / {} offline) + {} transfer tok | cow {} | evicted {} blocks | reclaimable {:.0} block·s",
            self.hit_rate * 100.0,
            self.hits,
            self.lookups,
            self.prefill_tokens_saved,
            self.online_tokens_saved,
            self.offline_tokens_saved,
            self.transfer_tokens_saved,
            self.cow_copies,
            self.evicted_blocks,
            self.reclaimed_block_s,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("enabled", Json::Bool(self.enabled)),
            ("lookups", Json::Num(self.lookups as f64)),
            ("hits", Json::Num(self.hits as f64)),
            ("hit_rate", Json::Num(self.hit_rate)),
            (
                "prefill_tokens_saved",
                Json::Num(self.prefill_tokens_saved as f64),
            ),
            (
                "online_tokens_saved",
                Json::Num(self.online_tokens_saved as f64),
            ),
            (
                "offline_tokens_saved",
                Json::Num(self.offline_tokens_saved as f64),
            ),
            (
                "transfer_tokens_saved",
                Json::Num(self.transfer_tokens_saved as f64),
            ),
            ("cow_copies", Json::Num(self.cow_copies as f64)),
            ("evicted_blocks", Json::Num(self.evicted_blocks as f64)),
            ("reclaimed_block_s", Json::Num(self.reclaimed_block_s)),
            (
                "cached_blocks_final",
                Json::Num(self.cached_blocks_final as f64),
            ),
        ])
    }
}

/// Chunked-prefill iteration metrics over one run (DESIGN.md §3.8):
/// chunk-budget utilization, prefill/decode interference delay, and the
/// preemption work retained by the cursor model vs. discarded by the old
/// exclusive-step truncation baseline.
#[derive(Debug, Clone)]
pub struct ChunkReport {
    pub enabled: bool,
    /// `ChunkMode` display form (`off` / `auto` / token count).
    pub mode: String,
    /// Composed iterations started.
    pub steps: u64,
    /// Composed iterations that genuinely mixed decode and prefill.
    pub mixed_steps: u64,
    /// Prefill chunk segments scheduled.
    pub prefill_chunks: u64,
    /// Uncached prompt tokens prefilled through chunks.
    pub prefill_tokens: u64,
    /// Σ per-iteration chunk budgets over iterations that scheduled at
    /// least one segment.
    pub budget_offered_tokens: u64,
    /// `prefill_tokens / budget_offered_tokens` (0 when nothing offered).
    pub budget_utilization: f64,
    /// Σ over mixed iterations of (composed − pure-decode) latency: the
    /// delay chunked prefill adds to co-resident decodes.
    pub interference_delay_s: f64,
    /// Online-over-offline preemption events (chunk-granular halts in
    /// chunked mode; layer-level truncations in exclusive mode).
    pub preemptions: u64,
    /// Prefill work retained across preemptions by the progress cursors,
    /// measured against the discard-and-recompute counterfactual:
    /// *each* preemption books the computed cursor progress that one
    /// exclusive-step truncation would have thrown away at that moment.
    /// Deliberately cumulative — the baseline re-prefills from scratch
    /// after every truncation, so a request preempted twice at cursors
    /// 512 and 3584 really would have recomputed 512 + 3584 tokens.
    pub preempted_work_retained: u64,
    /// Prefill work discarded by exclusive-step truncation (always 0 when
    /// chunking is on — asserted by the CI smoke).
    pub preempted_work_discarded: u64,
    /// Cursor/target mismatches at prefill completion (must stay 0).
    pub accounting_errors: u64,
}

impl ChunkReport {
    /// One-line summary for bench output.
    pub fn summary_line(&self) -> String {
        if !self.enabled {
            return format!(
                "chunk: off (exclusive steps) | preemptions {} discarded {} tok",
                self.preemptions, self.preempted_work_discarded
            );
        }
        format!(
            "chunk[{}]: {} iters ({} mixed) | {} chunks, {} tok ({:.1}% of budget) | interference {:.2}s | preemptions {} retained {} tok discarded {}",
            self.mode,
            self.steps,
            self.mixed_steps,
            self.prefill_chunks,
            self.prefill_tokens,
            self.budget_utilization * 100.0,
            self.interference_delay_s,
            self.preemptions,
            self.preempted_work_retained,
            self.preempted_work_discarded,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("enabled", Json::Bool(self.enabled)),
            ("mode", Json::Str(self.mode.clone())),
            ("steps", Json::Num(self.steps as f64)),
            ("mixed_steps", Json::Num(self.mixed_steps as f64)),
            ("prefill_chunks", Json::Num(self.prefill_chunks as f64)),
            ("prefill_tokens", Json::Num(self.prefill_tokens as f64)),
            (
                "budget_offered_tokens",
                Json::Num(self.budget_offered_tokens as f64),
            ),
            ("budget_utilization", Json::Num(self.budget_utilization)),
            (
                "interference_delay_s",
                Json::Num(self.interference_delay_s),
            ),
            ("preemptions", Json::Num(self.preemptions as f64)),
            (
                "preempted_work_retained",
                Json::Num(self.preempted_work_retained as f64),
            ),
            (
                "preempted_work_discarded",
                Json::Num(self.preempted_work_discarded as f64),
            ),
            (
                "accounting_errors",
                Json::Num(self.accounting_errors as f64),
            ),
        ])
    }
}

/// Fleet-level metrics over one multi-replica run (DESIGN.md §3.9):
/// fault-injection accounting, availability, cross-replica work stealing,
/// and online latency during failover windows.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Replica groups in the fleet.
    pub replicas: usize,
    /// Instance crashes that actually fired.
    pub crashes: u64,
    /// Crashed instances that rejoined their pool.
    pub recoveries: u64,
    /// Scheduled/stochastic faults refused at fire time (target already
    /// down, out of range after a repartition, or last live in its pool).
    pub skipped_faults: u64,
    /// `1 − downtime_inst_s / (total_instances · end_time)`.
    pub availability: f64,
    /// Instance-seconds spent down, summed over all down windows.
    pub downtime_inst_s: f64,
    /// Requests whose resident KV was lost to a crash.
    pub crash_evictions: u64,
    /// KV tokens lost to crashes and recomputed from scratch.
    pub recompute_tokens: u64,
    /// KV tokens spared by advance-notice evacuation (streamed to staging
    /// or a live relaxed instance before the crash fired).
    pub evacuated_tokens: u64,
    /// Backlog entries moved between replicas by work stealing.
    pub steals: u64,
    /// Prompt tokens carried by stolen backlog entries.
    pub stolen_tokens: u64,
    /// TTFT of online requests finishing inside a down window.
    pub failover_ttft: Summary,
    /// Avg TPOT of online requests finishing inside a down window.
    pub failover_tpot: Summary,
    /// Unfinished requests not held by any scheduling structure of their
    /// assigned replica — must stay 0 (no request silently lost).
    pub accounting_errors: u64,
}

impl FleetReport {
    /// One-line summary for bench output.
    pub fn summary_line(&self) -> String {
        format!(
            "fleet[{}r]: avail {:.4} ({:.1} inst·s down) | crashes {} rec {} skip {} | lost {} req / {} tok, evac {} tok | steals {} ({} tok) | failover ttft p99 {:.3}s | acct errs {}",
            self.replicas,
            self.availability,
            self.downtime_inst_s,
            self.crashes,
            self.recoveries,
            self.skipped_faults,
            self.crash_evictions,
            self.recompute_tokens,
            self.evacuated_tokens,
            self.steals,
            self.stolen_tokens,
            self.failover_ttft.p99,
            self.accounting_errors,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("replicas", Json::Num(self.replicas as f64)),
            ("crashes", Json::Num(self.crashes as f64)),
            ("recoveries", Json::Num(self.recoveries as f64)),
            ("skipped_faults", Json::Num(self.skipped_faults as f64)),
            ("availability", Json::Num(self.availability)),
            ("downtime_inst_s", Json::Num(self.downtime_inst_s)),
            ("crash_evictions", Json::Num(self.crash_evictions as f64)),
            ("recompute_tokens", Json::Num(self.recompute_tokens as f64)),
            ("evacuated_tokens", Json::Num(self.evacuated_tokens as f64)),
            ("steals", Json::Num(self.steals as f64)),
            ("stolen_tokens", Json::Num(self.stolen_tokens as f64)),
            ("failover_ttft", self.failover_ttft.to_json()),
            ("failover_tpot", self.failover_tpot.to_json()),
            (
                "accounting_errors",
                Json::Num(self.accounting_errors as f64),
            ),
        ])
    }
}

/// Outcome snapshot for one finished (or dropped) request.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: u64,
    pub class: Class,
    pub arrival: f64,
    pub prompt_len: usize,
    pub output_len: usize,
    pub ttft: Option<f64>,
    pub avg_tpot: Option<f64>,
    pub finished_at: Option<f64>,
    pub evictions: u32,
}

impl RequestRecord {
    pub fn from_request(r: &Request) -> Self {
        RequestRecord {
            id: r.id,
            class: r.class,
            arrival: r.arrival,
            prompt_len: r.prompt_len,
            output_len: r.output_len,
            ttft: r.ttft(),
            avg_tpot: r.avg_tpot(),
            finished_at: r.finished_at,
            evictions: r.evictions,
        }
    }

    /// Does this (online) request violate its SLO? Unfinished requests and
    /// requests with no recorded first token count as violations.
    pub fn violates(&self, slo: &SloSpec) -> bool {
        match (self.ttft, self.finished_at) {
            (Some(ttft), Some(_)) => {
                ttft > slo.ttft
                    || self.avg_tpot.map(|t| t > slo.tpot).unwrap_or(false)
            }
            _ => true,
        }
    }
}

/// Aggregated experiment metrics.
#[derive(Debug, Clone)]
pub struct Report {
    pub duration_s: f64,
    pub online_total: usize,
    pub online_finished: usize,
    pub online_violations: usize,
    pub online_violation_rate: f64,
    pub ttft: Summary,
    pub tpot: Summary,
    pub offline_total: usize,
    pub offline_finished: usize,
    /// Offline output tokens per second (the paper's offline throughput).
    pub offline_token_throughput: f64,
    /// Offline finished requests per second.
    pub offline_request_throughput: f64,
    /// Total offline tokens recomputed due to evictions.
    pub offline_evictions: u64,
}

impl Report {
    pub fn meets_slo(&self, slo: &SloSpec) -> bool {
        self.online_violation_rate <= slo.violation_threshold
    }

    /// Fraction of online requests that met both SLOs — the quantity
    /// `--slo-gate` thresholds and the burn-rate watchdog tracks.
    pub fn slo_attainment(&self) -> f64 {
        1.0 - self.online_violation_rate
    }

    /// One-line summary for bench output.
    pub fn summary_line(&self) -> String {
        format!(
            "online {}/{} fin, viol {:.2}% | ttft p50 {:.3}s p99 {:.3}s | tpot p50 {:.1}ms p99 {:.1}ms | offline {}/{} fin, {:.1} tok/s",
            self.online_finished,
            self.online_total,
            self.online_violation_rate * 100.0,
            self.ttft.p50,
            self.ttft.p99,
            self.tpot.p50 * 1e3,
            self.tpot.p99 * 1e3,
            self.offline_finished,
            self.offline_total,
            self.offline_token_throughput,
        )
    }

    /// Machine-readable form: the full report including the online
    /// TTFT/TPOT percentile summaries, for cross-run comparisons.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("duration_s", Json::Num(self.duration_s)),
            ("online_total", Json::Num(self.online_total as f64)),
            ("online_finished", Json::Num(self.online_finished as f64)),
            (
                "online_violations",
                Json::Num(self.online_violations as f64),
            ),
            (
                "online_violation_rate",
                Json::Num(self.online_violation_rate),
            ),
            ("slo_attainment", Json::Num(self.slo_attainment())),
            ("ttft", self.ttft.to_json()),
            ("tpot", self.tpot.to_json()),
            ("offline_total", Json::Num(self.offline_total as f64)),
            ("offline_finished", Json::Num(self.offline_finished as f64)),
            (
                "offline_token_throughput",
                Json::Num(self.offline_token_throughput),
            ),
            (
                "offline_request_throughput",
                Json::Num(self.offline_request_throughput),
            ),
            (
                "offline_evictions",
                Json::Num(self.offline_evictions as f64),
            ),
        ])
    }
}

/// Streaming per-request metrics accumulator: ingests request outcomes one
/// at a time and keeps only O(histogram-buckets) state — counters plus
/// [`LatencySummary`] histograms — so multi-million-request traces never
/// materialize a `Vec<f64>` of latencies (DESIGN.md §3.10). The SLO is
/// fixed at construction because violation classification happens at
/// ingest, not at report time. Under `cfg(test)` the recorder keeps the
/// raw samples it would otherwise discard and [`Recorder::report`]
/// re-proves the streamed summaries against an exact sorted replay
/// (DESIGN.md §3.13).
#[derive(Debug, Clone)]
pub struct Recorder {
    slo: SloSpec,
    online_total: usize,
    online_finished: usize,
    online_violations: usize,
    ttft: LatencySummary,
    tpot: LatencySummary,
    offline_total: usize,
    offline_finished: usize,
    offline_tokens: f64,
    offline_evictions: u64,
    /// Exact-replay mirrors of the streaming histograms' inputs.
    #[cfg(test)]
    ttft_replay: Vec<f64>,
    #[cfg(test)]
    tpot_replay: Vec<f64>,
}

/// Check a streamed [`Summary`] against the raw samples it was built
/// from: exact count/min/max, near-exact moments, and quantiles within
/// the documented one-bucket relative width of the same-rank order
/// statistic (the streamed estimator's own rank convention, so the bound
/// is a theorem of the bucket layout, not a statistical hope).
#[cfg(test)]
fn assert_streamed_matches_replay(
    name: &str,
    replay: &[f64],
    streamed: &Summary,
) {
    assert_eq!(streamed.count, replay.len(), "{name}: sample count");
    if replay.is_empty() {
        return;
    }
    let mut sorted = replay.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    assert_eq!(streamed.min, sorted[0], "{name}: exact min");
    assert_eq!(streamed.max, sorted[sorted.len() - 1], "{name}: exact max");
    let exact = Summary::of(&sorted);
    let moment_tol = 1e-6 * exact.mean.abs().max(1.0);
    assert!(
        (streamed.mean - exact.mean).abs() <= moment_tol,
        "{name}: mean {} vs exact {}",
        streamed.mean,
        exact.mean
    );
    assert!(
        (streamed.std - exact.std).abs() <= moment_tol,
        "{name}: std {} vs exact {}",
        streamed.std,
        exact.std
    );
    let tol = LatencySummary::bucket_relative_width();
    for (p, est) in
        [(50.0, streamed.p50), (90.0, streamed.p90), (99.0, streamed.p99)]
    {
        let rank =
            ((p / 100.0) * sorted.len() as f64).ceil().max(1.0) as usize;
        let stat = sorted[rank - 1];
        assert!(
            (est - stat).abs() <= stat.abs() * tol + 1e-7,
            "{name} p{p}: streamed {est} vs rank statistic {stat} \
             (tol {tol})"
        );
    }
}

impl Recorder {
    pub fn new(slo: &SloSpec) -> Self {
        Recorder {
            slo: *slo,
            online_total: 0,
            online_finished: 0,
            online_violations: 0,
            ttft: LatencySummary::new(),
            tpot: LatencySummary::new(),
            offline_total: 0,
            offline_finished: 0,
            offline_tokens: 0.0,
            offline_evictions: 0,
            #[cfg(test)]
            ttft_replay: Vec::new(),
            #[cfg(test)]
            tpot_replay: Vec::new(),
        }
    }

    pub fn record(&mut self, r: &Request) {
        self.push(RequestRecord::from_request(r));
    }

    pub fn push(&mut self, rec: RequestRecord) {
        let _p = crate::obs::scope(crate::obs::Subsystem::Metrics);
        match rec.class {
            Class::Online => {
                self.online_total += 1;
                if rec.finished_at.is_some() {
                    self.online_finished += 1;
                }
                if rec.violates(&self.slo) {
                    self.online_violations += 1;
                }
                if let Some(t) = rec.ttft {
                    self.ttft.record(t);
                    #[cfg(test)]
                    self.ttft_replay.push(t);
                }
                if let Some(t) = rec.avg_tpot {
                    self.tpot.record(t);
                    #[cfg(test)]
                    self.tpot_replay.push(t);
                }
            }
            Class::Offline => {
                self.offline_total += 1;
                if rec.finished_at.is_some() {
                    self.offline_finished += 1;
                    self.offline_tokens += rec.output_len as f64;
                }
                self.offline_evictions += rec.evictions as u64;
            }
        }
    }

    /// Requests ingested so far.
    pub fn count(&self) -> usize {
        self.online_total + self.offline_total
    }

    /// Build the aggregate report. `duration_s` is the observation window
    /// used for throughput denominators.
    pub fn report(&self, duration_s: f64) -> Report {
        let dur = duration_s.max(1e-9);
        let report = Report {
            duration_s,
            online_total: self.online_total,
            online_finished: self.online_finished,
            online_violations: self.online_violations,
            online_violation_rate: if self.online_total == 0 {
                0.0
            } else {
                self.online_violations as f64 / self.online_total as f64
            },
            ttft: self.ttft.summary(),
            tpot: self.tpot.summary(),
            offline_total: self.offline_total,
            offline_finished: self.offline_finished,
            offline_token_throughput: self.offline_tokens / dur,
            offline_request_throughput: self.offline_finished as f64 / dur,
            offline_evictions: self.offline_evictions,
        };
        #[cfg(test)]
        {
            assert_streamed_matches_replay(
                "ttft",
                &self.ttft_replay,
                &report.ttft,
            );
            assert_streamed_matches_replay(
                "tpot",
                &self.tpot_replay,
                &report.tpot,
            );
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finished_online(id: u64, ttft: f64, tpot: f64, out: usize) -> RequestRecord {
        RequestRecord {
            id,
            class: Class::Online,
            arrival: 0.0,
            prompt_len: 100,
            output_len: out,
            ttft: Some(ttft),
            avg_tpot: Some(tpot),
            finished_at: Some(ttft + tpot * (out - 1) as f64),
            evictions: 0,
        }
    }

    fn finished_offline(id: u64, out: usize, done: f64) -> RequestRecord {
        RequestRecord {
            id,
            class: Class::Offline,
            arrival: 0.0,
            prompt_len: 100,
            output_len: out,
            ttft: Some(1.0),
            avg_tpot: Some(0.2),
            finished_at: Some(done),
            evictions: 1,
        }
    }

    #[test]
    fn violation_rules() {
        let slo = SloSpec {
            ttft: 5.0,
            tpot: 0.1,
            violation_threshold: 0.03,
        };
        assert!(!finished_online(1, 2.0, 0.05, 10).violates(&slo));
        assert!(finished_online(2, 6.0, 0.05, 10).violates(&slo)); // TTFT
        assert!(finished_online(3, 2.0, 0.15, 10).violates(&slo)); // TPOT
        // Unfinished counts as violation.
        let mut r = finished_online(4, 2.0, 0.05, 10);
        r.finished_at = None;
        assert!(r.violates(&slo));
        let mut r = finished_online(5, 2.0, 0.05, 10);
        r.ttft = None;
        assert!(r.violates(&slo));
    }

    #[test]
    fn report_aggregates() {
        let slo = SloSpec::default();
        let mut rec = Recorder::new(&slo);
        rec.push(finished_online(1, 1.0, 0.05, 100));
        rec.push(finished_online(2, 9.0, 0.05, 100)); // ttft violation
        rec.push(finished_offline(3, 500, 50.0));
        rec.push(finished_offline(4, 300, 80.0));
        assert_eq!(rec.count(), 4);
        let rep = rec.report(100.0);
        assert_eq!(rep.online_total, 2);
        assert_eq!(rep.online_violations, 1);
        assert!((rep.online_violation_rate - 0.5).abs() < 1e-12);
        assert_eq!(rep.offline_finished, 2);
        assert!((rep.offline_token_throughput - 8.0).abs() < 1e-12);
        assert!((rep.offline_request_throughput - 0.02).abs() < 1e-12);
        assert_eq!(rep.offline_evictions, 2);
        assert!(!rep.meets_slo(&slo)); // 50% > 3%
    }

    #[test]
    fn transport_report_summary_line() {
        let rep = TransportReport {
            links: vec![LinkReport {
                name: "pool".into(),
                bytes_moved: 5e6,
                busy_s: 2.0,
                utilization: 0.2,
                jobs_completed: 3,
                stall_s: 0.5,
            }],
            stall_s: 0.5,
            rescues: 2,
            offloads: 1,
            restores: 1,
            restart_latency: Summary::of(&[0.1, 0.2]),
            bytes_enqueued: 5e6,
            bytes_delivered: 5e6,
            jobs_cancelled: 0,
        };
        let line = rep.summary_line();
        assert!(line.contains("pool"), "{line}");
        assert!(line.contains("rescues 2"), "{line}");
    }

    #[test]
    fn report_json_is_machine_readable() {
        let slo = SloSpec::default();
        let mut rec = Recorder::new(&slo);
        rec.push(finished_online(1, 1.0, 0.05, 100));
        rec.push(finished_offline(2, 500, 50.0));
        let rep = rec.report(100.0);
        let j = rep.to_json();
        assert_eq!(j.get("online_total").as_f64(), Some(1.0));
        assert_eq!(j.get("slo_attainment").as_f64(), Some(1.0));
        assert_eq!(j.get("ttft").get("p50").as_f64(), Some(1.0));
        assert_eq!(j.get("offline_token_throughput").as_f64(), Some(5.0));
        // Round-trips through the parser.
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn pool_report_summary_and_json() {
        let rep = PoolReport {
            policy: "periodic(epoch=60,headroom=0.15)".into(),
            plans: 4,
            flips: 2,
            epochs: vec![
                PoolEpoch {
                    at: 60.0,
                    relaxed: 2,
                    strict: 2,
                    planned_strict: 3,
                    est_online_rate: 4.0,
                    est_offline_rate: 1.0,
                },
                PoolEpoch {
                    at: 120.0,
                    relaxed: 1,
                    strict: 3,
                    planned_strict: 3,
                    est_online_rate: 4.2,
                    est_offline_rate: 1.0,
                },
            ],
            transition_s: Summary::of(&[4.0, 6.0]),
            stranded_instance_s: 60.0,
            final_relaxed: 1,
            final_strict: 3,
        };
        let line = rep.summary_line();
        assert!(line.contains("plans 4"), "{line}");
        assert!(line.contains("flips 2"), "{line}");
        assert!(line.contains("strict 2..3"), "{line}");
        let j = rep.to_json();
        assert_eq!(j.get("flips").as_f64(), Some(2.0));
        assert_eq!(j.get("epochs").idx(1).get("strict").as_f64(), Some(3.0));
        assert_eq!(
            j.get("epochs").idx(0).get("est_online_rate").as_f64(),
            Some(4.0)
        );
    }

    #[test]
    fn prefix_report_summary_and_json() {
        let rep = PrefixReport {
            enabled: true,
            lookups: 10,
            hits: 7,
            hit_rate: 0.42,
            prefill_tokens_saved: 4200,
            online_tokens_saved: 1200,
            offline_tokens_saved: 3000,
            transfer_tokens_saved: 500,
            cow_copies: 3,
            evicted_blocks: 9,
            reclaimed_block_s: 120.5,
            cached_blocks_final: 11,
        };
        let line = rep.summary_line();
        assert!(line.contains("hit 42.0%"), "{line}");
        assert!(line.contains("cow 3"), "{line}");
        let j = rep.to_json();
        assert_eq!(j.get("hit_rate").as_f64(), Some(0.42));
        assert_eq!(j.get("prefill_tokens_saved").as_f64(), Some(4200.0));
        assert_eq!(j.get("evicted_blocks").as_f64(), Some(9.0));
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        let off = PrefixReport {
            enabled: false,
            ..rep
        };
        assert_eq!(off.summary_line(), "prefix: disabled");
    }

    #[test]
    fn chunk_report_summary_and_json() {
        let rep = ChunkReport {
            enabled: true,
            mode: "auto".into(),
            steps: 100,
            mixed_steps: 40,
            prefill_chunks: 60,
            prefill_tokens: 48_000,
            budget_offered_tokens: 60_000,
            budget_utilization: 0.8,
            interference_delay_s: 1.25,
            preemptions: 5,
            preempted_work_retained: 9_000,
            preempted_work_discarded: 0,
            accounting_errors: 0,
        };
        let line = rep.summary_line();
        assert!(line.contains("auto"), "{line}");
        assert!(line.contains("retained 9000"), "{line}");
        let j = rep.to_json();
        assert_eq!(j.get("budget_utilization").as_f64(), Some(0.8));
        assert_eq!(j.get("preempted_work_discarded").as_f64(), Some(0.0));
        assert_eq!(j.get("prefill_tokens").as_f64(), Some(48_000.0));
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        let off = ChunkReport {
            enabled: false,
            ..rep
        };
        assert!(off.summary_line().contains("exclusive"));
    }

    #[test]
    fn streaming_report_matches_exact_replay() {
        // Log-spread TTFTs over three decades plus oscillating TPOTs —
        // `report` itself asserts the streamed summaries sit within one
        // bucket of the exact sorted replay.
        let slo = SloSpec::default();
        let mut rec = Recorder::new(&slo);
        for i in 0..2000u64 {
            let ttft = 1e-3 * 10f64.powf(3.0 * (i as f64) / 2000.0);
            let tpot = 0.01 + (i as f64).sin().abs() * 0.2;
            rec.push(finished_online(i, ttft, tpot, 64));
        }
        let rep = rec.report(500.0);
        assert_eq!(rep.online_total, 2000);
        assert_eq!(rep.ttft.count, 2000);
        assert!(rep.ttft.p99 > rep.ttft.p50);
    }

    #[test]
    fn empty_report() {
        let rep = Recorder::new(&SloSpec::default()).report(10.0);
        assert_eq!(rep.online_total, 0);
        assert_eq!(rep.online_violation_rate, 0.0);
        assert!(rep.meets_slo(&SloSpec::default()));
        assert!(!rep.summary_line().is_empty());
    }

    #[test]
    fn from_request_snapshot() {
        let mut r = Request::new(7, Class::Online, 10.0, 50, 3);
        r.mark_first_token(11.0);
        r.mark_token(11.5);
        r.mark_token(12.0);
        let rec = RequestRecord::from_request(&r);
        assert_eq!(rec.ttft, Some(1.0));
        assert_eq!(rec.finished_at, Some(12.0));
        assert!((rec.avg_tpot.unwrap() - 0.5).abs() < 1e-12);
    }
}
