//! Configuration: model dimensions, hardware profiles, SLOs, cluster shape,
//! scheduler parameters.
//!
//! Everything is constructible in code (named presets used by the benches)
//! and loadable from JSON (`configs/*.json`) so deployments can override any
//! field without recompiling — the "real config system" role a framework
//! like vLLM/MaxText plays.

use crate::util::json::Json;

/// Decoder-only transformer dimensions — enough to drive the operator-level
/// performance model of §3.3. Presets carry the true Qwen2.5 numbers used in
/// the paper's evaluation.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub layers: usize,
    pub hidden: usize,
    pub q_heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub vocab: usize,
    /// Bytes per value (Table 2's `d`, e.g. 2 for bf16).
    pub bytes_per_value: f64,
    /// Tensor-parallel degree of one serving instance (divides per-chip work).
    pub tensor_parallel: usize,
}

impl std::str::FromStr for ModelSpec {
    type Err = anyhow::Error;

    /// Parse a named model preset (consistent with `Policy`/`Ablation`).
    fn from_str(name: &str) -> anyhow::Result<ModelSpec> {
        match name {
            "qwen2.5-7b" | "7b" => Ok(ModelSpec::qwen2_5_7b()),
            "qwen2.5-72b" | "72b" => Ok(ModelSpec::qwen2_5_72b()),
            "tiny" => Ok(ModelSpec::tiny()),
            other => anyhow::bail!("unknown model preset `{other}`"),
        }
    }
}

impl std::fmt::Display for ModelSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name)
    }
}

impl ModelSpec {
    /// Qwen2.5 7B (bf16) — the paper's primary model, 1 chip per instance.
    pub fn qwen2_5_7b() -> Self {
        ModelSpec {
            name: "qwen2.5-7b".into(),
            layers: 28,
            hidden: 3584,
            q_heads: 28,
            kv_heads: 4,
            head_dim: 128,
            ffn: 18944,
            vocab: 152064,
            bytes_per_value: 2.0,
            tensor_parallel: 1,
        }
    }

    /// Qwen2.5 72B (bf16) — deployed with TP=4 in the paper's evaluation.
    pub fn qwen2_5_72b() -> Self {
        ModelSpec {
            name: "qwen2.5-72b".into(),
            layers: 80,
            hidden: 8192,
            q_heads: 64,
            kv_heads: 8,
            head_dim: 128,
            ffn: 29568,
            vocab: 152064,
            bytes_per_value: 2.0,
            tensor_parallel: 4,
        }
    }

    /// The tiny synthetic-weight model the AOT artifacts implement (f32).
    pub fn tiny() -> Self {
        ModelSpec {
            name: "tiny".into(),
            layers: 4,
            hidden: 256,
            q_heads: 8,
            kv_heads: 2,
            head_dim: 32,
            ffn: 512,
            vocab: 512,
            bytes_per_value: 4.0,
            tensor_parallel: 1,
        }
    }

    /// KV-cache bytes for one token (all layers, K and V).
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.layers as f64
            * self.kv_heads as f64
            * self.head_dim as f64
            * self.bytes_per_value
    }

    /// Total parameter count (embedding + per-layer weights + untied head).
    pub fn param_count(&self) -> f64 {
        let h = self.hidden as f64;
        let kv_dim = (self.kv_heads * self.head_dim) as f64;
        let per_layer = h * h // wq
            + 2.0 * h * kv_dim // wk, wv
            + h * h // wo
            + 3.0 * h * self.ffn as f64 // gate, up, down
            + 2.0 * h; // norms
        self.vocab as f64 * h * 2.0 + per_layer * self.layers as f64 + h
    }

    pub fn weights_bytes(&self) -> f64 {
        self.param_count() * self.bytes_per_value
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        Ok(ModelSpec {
            name: v.req_str("name")?.to_string(),
            layers: v.req_usize("layers")?,
            hidden: v.req_usize("hidden")?,
            q_heads: v.req_usize("q_heads")?,
            kv_heads: v.req_usize("kv_heads")?,
            head_dim: v.req_usize("head_dim")?,
            ffn: v.req_usize("ffn")?,
            vocab: v.req_usize("vocab")?,
            bytes_per_value: v.req_f64("bytes_per_value")?,
            tensor_parallel: v.get("tensor_parallel").as_usize().unwrap_or(1),
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("layers", Json::Num(self.layers as f64)),
            ("hidden", Json::Num(self.hidden as f64)),
            ("q_heads", Json::Num(self.q_heads as f64)),
            ("kv_heads", Json::Num(self.kv_heads as f64)),
            ("head_dim", Json::Num(self.head_dim as f64)),
            ("ffn", Json::Num(self.ffn as f64)),
            ("vocab", Json::Num(self.vocab as f64)),
            ("bytes_per_value", Json::Num(self.bytes_per_value)),
            ("tensor_parallel", Json::Num(self.tensor_parallel as f64)),
        ])
    }
}

/// Achievable-rate hardware profile: the Table 4 parameters plus memory
/// capacity. Values are *achievable* (measured/profiled), not theoretical
/// peaks — exactly how the paper parameterizes its roofline model.
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareProfile {
    pub name: String,
    /// F_g — achievable FLOP/s for GEMM operators.
    pub flops_gemm: f64,
    /// F_ap — achievable FLOP/s for prefill attention.
    pub flops_attn_prefill: f64,
    /// F_ad — achievable FLOP/s for decode attention.
    pub flops_attn_decode: f64,
    /// M_g — achievable bytes/s for GEMM operators.
    pub bw_gemm: f64,
    /// M_a — achievable bytes/s for attention operators.
    pub bw_attn: f64,
    /// O_p — static per-iteration overhead for prefill (s).
    pub overhead_prefill: f64,
    /// O_d — static per-iteration overhead for decode (s).
    pub overhead_decode: f64,
    /// B_c — effective interconnect bandwidth for KV transfer (bytes/s).
    pub bw_comm: f64,
    /// Device memory per chip (bytes) available for weights + KV cache.
    pub mem_capacity: f64,
}

impl std::str::FromStr for HardwareProfile {
    type Err = anyhow::Error;

    /// Parse a named hardware preset (consistent with `Policy`/`Ablation`).
    fn from_str(name: &str) -> anyhow::Result<HardwareProfile> {
        match name {
            "ascend-910c" | "910c" => Ok(HardwareProfile::ascend_910c()),
            "h800" => Ok(HardwareProfile::h800()),
            "ascend-910c-vllm" | "910c-vllm" => {
                Ok(HardwareProfile::ascend_910c_vllm())
            }
            "cpu-tiny" => Ok(HardwareProfile::cpu_tiny()),
            other => anyhow::bail!("unknown hardware preset `{other}`"),
        }
    }
}

impl std::fmt::Display for HardwareProfile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name)
    }
}

impl HardwareProfile {
    /// Ascend 910c single chip. The paper states one 910c chip is comparable
    /// to an NVIDIA A100 SXM (312 TFLOP/s bf16, ~2.0 TB/s HBM); achievable
    /// fractions follow the PRoof-style profiling the paper cites.
    pub fn ascend_910c() -> Self {
        let peak_flops = 312e12;
        let peak_bw = 2.0e12;
        HardwareProfile {
            name: "ascend-910c".into(),
            flops_gemm: 0.62 * peak_flops,
            flops_attn_prefill: 0.45 * peak_flops,
            flops_attn_decode: 0.25 * peak_flops,
            bw_gemm: 0.65 * peak_bw,
            bw_attn: 0.80 * peak_bw,
            overhead_prefill: 5.0e-3,
            overhead_decode: 2.0e-3,
            bw_comm: 25e9, // RDMA effective
            // "comparable to the NVIDIA A100 SXM" (§5.1.1) — the 80 GB part.
            mem_capacity: 80e9,
        }
    }

    /// NVIDIA H800-like profile. Table 6 observes ~3x the single-910c-chip
    /// throughput, "consistent with their theoretical peak FLOPs/s ratio".
    pub fn h800() -> Self {
        let peak_flops = 3.0 * 312e12;
        let peak_bw = 3.35e12;
        HardwareProfile {
            name: "h800".into(),
            flops_gemm: 0.62 * peak_flops,
            flops_attn_prefill: 0.45 * peak_flops,
            flops_attn_decode: 0.25 * peak_flops,
            bw_gemm: 0.65 * peak_bw,
            bw_attn: 0.80 * peak_bw,
            overhead_prefill: 4.0e-3,
            overhead_decode: 1.5e-3,
            bw_comm: 50e9,
            mem_capacity: 80e9,
        }
    }

    /// A deliberately less-optimized 910c profile representing vLLM on the
    /// same chip (Table 6 shows xLLM ~1.2x vLLM on the 910c).
    pub fn ascend_910c_vllm() -> Self {
        let mut p = Self::ascend_910c();
        p.name = "ascend-910c-vllm".into();
        p.flops_gemm *= 0.87;
        p.flops_attn_prefill *= 0.80;
        p.flops_attn_decode *= 0.80;
        p.bw_gemm *= 0.85;
        p.bw_attn *= 0.82;
        p.overhead_prefill = 6.5e-3;
        p.overhead_decode = 2.8e-3;
        p
    }

    /// Host-CPU profile for the tiny model; calibrated at runtime against
    /// measured PJRT latencies (`perfmodel::calibrate`).
    pub fn cpu_tiny() -> Self {
        HardwareProfile {
            name: "cpu-tiny".into(),
            flops_gemm: 5e10,
            flops_attn_prefill: 2e10,
            flops_attn_decode: 1e10,
            bw_gemm: 2e10,
            bw_attn: 2e10,
            overhead_prefill: 2e-3,
            overhead_decode: 1e-3,
            bw_comm: 5e9,
            mem_capacity: 2e9,
        }
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        Ok(HardwareProfile {
            name: v.req_str("name")?.to_string(),
            flops_gemm: v.req_f64("flops_gemm")?,
            flops_attn_prefill: v.req_f64("flops_attn_prefill")?,
            flops_attn_decode: v.req_f64("flops_attn_decode")?,
            bw_gemm: v.req_f64("bw_gemm")?,
            bw_attn: v.req_f64("bw_attn")?,
            overhead_prefill: v.req_f64("overhead_prefill")?,
            overhead_decode: v.req_f64("overhead_decode")?,
            bw_comm: v.req_f64("bw_comm")?,
            mem_capacity: v.req_f64("mem_capacity")?,
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("flops_gemm", Json::Num(self.flops_gemm)),
            ("flops_attn_prefill", Json::Num(self.flops_attn_prefill)),
            ("flops_attn_decode", Json::Num(self.flops_attn_decode)),
            ("bw_gemm", Json::Num(self.bw_gemm)),
            ("bw_attn", Json::Num(self.bw_attn)),
            ("overhead_prefill", Json::Num(self.overhead_prefill)),
            ("overhead_decode", Json::Num(self.overhead_decode)),
            ("bw_comm", Json::Num(self.bw_comm)),
            ("mem_capacity", Json::Num(self.mem_capacity)),
        ])
    }
}

/// How concurrent transfer jobs share one link's bandwidth.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkSharing {
    /// Jobs are served to completion in enqueue order.
    Fifo,
    /// Active jobs round-robin at chunk granularity (processor sharing
    /// approximated at the layer-chunk level).
    FairShare,
}

impl std::str::FromStr for LinkSharing {
    type Err = anyhow::Error;

    fn from_str(name: &str) -> anyhow::Result<LinkSharing> {
        match name {
            "fifo" => Ok(LinkSharing::Fifo),
            "fair-share" | "fair_share" => Ok(LinkSharing::FairShare),
            other => anyhow::bail!("unknown link sharing `{other}`"),
        }
    }
}

impl std::fmt::Display for LinkSharing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            LinkSharing::Fifo => "fifo",
            LinkSharing::FairShare => "fair-share",
        })
    }
}

/// One named interconnect link of the cluster's KV-transport topology.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkSpec {
    pub name: String,
    /// Achievable bandwidth (bytes/s).
    pub bandwidth: f64,
    /// Per-chunk setup latency (s) — RDMA post/doorbell cost.
    pub latency: f64,
    pub sharing: LinkSharing,
}

impl LinkSpec {
    /// Parse from JSON, falling back to `base` for absent fields.
    pub fn from_json(v: &Json, base: &LinkSpec) -> anyhow::Result<Self> {
        Ok(LinkSpec {
            name: v
                .get("name")
                .as_str()
                .unwrap_or(&base.name)
                .to_string(),
            bandwidth: v.get("bandwidth").as_f64().unwrap_or(base.bandwidth),
            latency: v.get("latency").as_f64().unwrap_or(base.latency),
            sharing: match v.get("sharing").as_str() {
                Some(s) => s.parse()?,
                None => base.sharing,
            },
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("bandwidth", Json::Num(self.bandwidth)),
            ("latency", Json::Num(self.latency)),
            ("sharing", Json::Str(self.sharing.to_string())),
        ])
    }
}

/// KV-transport topology and fast-preemption knobs (`transport` section of
/// the JSON config — see DESIGN.md §3.5 for the schema).
#[derive(Debug, Clone, PartialEq)]
pub struct TransportSpec {
    /// Model layers moved per transfer chunk (§3.4.1 layer-wise
    /// granularity; 1 = one chunk per layer).
    pub chunk_layers: usize,
    /// Fast preemption: stream evicted offline KV out (to the relaxed pool
    /// or host staging) instead of discarding it for full recompute.
    pub recoverable_eviction: bool,
    /// Allow the host-staging buffer as an eviction destination when no
    /// relaxed instance has room.
    pub host_staging: bool,
    /// Inter-pool interconnect (relaxed <-> strict KV movement).
    pub pool: LinkSpec,
    /// Device <-> host staging link (recoverable-eviction offload/restore).
    pub host: LinkSpec,
}

impl TransportSpec {
    /// Defaults derived from a hardware profile: the pool link carries the
    /// profile's effective interconnect bandwidth (`B_c`); host staging
    /// moves over the (faster) device-to-host DMA path.
    pub fn for_hardware(hw: &HardwareProfile) -> Self {
        TransportSpec {
            chunk_layers: 1,
            recoverable_eviction: true,
            host_staging: true,
            pool: LinkSpec {
                name: "pool".into(),
                bandwidth: hw.bw_comm,
                latency: 5e-6,
                sharing: LinkSharing::Fifo,
            },
            host: LinkSpec {
                name: "host".into(),
                bandwidth: 2.0 * hw.bw_comm,
                latency: 5e-6,
                sharing: LinkSharing::Fifo,
            },
        }
    }

    /// Parse the `transport` config section; absent fields fall back to the
    /// hardware-derived defaults in `base`.
    pub fn from_json(v: &Json, base: &TransportSpec) -> anyhow::Result<Self> {
        Ok(TransportSpec {
            chunk_layers: v
                .get("chunk_layers")
                .as_usize()
                .unwrap_or(base.chunk_layers)
                .max(1),
            recoverable_eviction: v
                .get("recoverable_eviction")
                .as_bool()
                .unwrap_or(base.recoverable_eviction),
            host_staging: v
                .get("host_staging")
                .as_bool()
                .unwrap_or(base.host_staging),
            pool: match v.get("pool") {
                Json::Null => base.pool.clone(),
                p => LinkSpec::from_json(p, &base.pool)?,
            },
            host: match v.get("host") {
                Json::Null => base.host.clone(),
                h => LinkSpec::from_json(h, &base.host)?,
            },
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("chunk_layers", Json::Num(self.chunk_layers as f64)),
            (
                "recoverable_eviction",
                Json::Bool(self.recoverable_eviction),
            ),
            ("host_staging", Json::Bool(self.host_staging)),
            ("pool", self.pool.to_json()),
            ("host", self.host.to_json()),
        ])
    }
}

/// Elastic pool-manager policy (DESIGN.md §3.6): how — and whether — the
/// strict/relaxed instance split is re-planned at runtime.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum PoolPolicy {
    /// Frozen config-time split (the pre-elastic behaviour).
    #[default]
    Static,
    /// Re-plan every `epoch_s` seconds: the Roofline-guided planner sizes
    /// the strict pool for the estimated load with `headroom` kept under
    /// the TPOT SLO.
    Periodic {
        epoch_s: f64,
        /// Fraction of the TPOT budget held back when sizing (0..0.9).
        headroom: f64,
    },
    /// Threshold-triggered: grow the strict pool when estimated decode
    /// pressure exceeds `up`, shrink when the pool one instance smaller
    /// would still sit below `down`; at most one transition per
    /// `cooldown_s`.
    Reactive {
        up: f64,
        down: f64,
        cooldown_s: f64,
    },
}

impl PoolPolicy {
    pub const DEFAULT_PERIODIC: PoolPolicy = PoolPolicy::Periodic {
        epoch_s: 60.0,
        headroom: 0.15,
    };
    pub const DEFAULT_REACTIVE: PoolPolicy = PoolPolicy::Reactive {
        up: 0.85,
        down: 0.5,
        cooldown_s: 30.0,
    };

    /// Does this policy ever repartition at runtime?
    pub fn is_elastic(&self) -> bool {
        !matches!(self, PoolPolicy::Static)
    }
}

impl std::str::FromStr for PoolPolicy {
    type Err = anyhow::Error;

    /// Parse `static`, `periodic`, `reactive`, or the parameterized forms
    /// `Display` emits — `periodic(epoch=60,headroom=0.15)` and
    /// `reactive(up=0.85,down=0.5,cooldown=30)` (keys optional, any order).
    fn from_str(name: &str) -> anyhow::Result<PoolPolicy> {
        fn params<'a>(
            body: &'a str,
            kind: &str,
        ) -> anyhow::Result<Vec<(&'a str, f64)>> {
            let mut out = Vec::new();
            for tok in body.split(',').filter(|t| !t.trim().is_empty()) {
                let (k, v) = tok
                    .trim()
                    .split_once('=')
                    .ok_or_else(|| {
                        anyhow::anyhow!("bad {kind} parameter `{tok}`")
                    })?;
                out.push((k.trim(), v.trim().parse::<f64>()?));
            }
            Ok(out)
        }
        match name {
            "static" => return Ok(PoolPolicy::Static),
            "periodic" => return Ok(PoolPolicy::DEFAULT_PERIODIC),
            "reactive" => return Ok(PoolPolicy::DEFAULT_REACTIVE),
            _ => {}
        }
        if let Some(body) = name
            .strip_prefix("periodic(")
            .and_then(|s| s.strip_suffix(')'))
        {
            let (mut epoch_s, mut headroom) =
                match PoolPolicy::DEFAULT_PERIODIC {
                    PoolPolicy::Periodic { epoch_s, headroom } => {
                        (epoch_s, headroom)
                    }
                    _ => unreachable!(),
                };
            for (k, v) in params(body, "periodic")? {
                match k {
                    "epoch" | "epoch_s" => epoch_s = v,
                    "headroom" => headroom = v,
                    _ => anyhow::bail!("unknown periodic parameter `{k}`"),
                }
            }
            anyhow::ensure!(epoch_s > 0.0, "epoch must be positive");
            anyhow::ensure!(
                (0.0..0.9).contains(&headroom),
                "headroom must be in [0, 0.9)"
            );
            return Ok(PoolPolicy::Periodic { epoch_s, headroom });
        }
        if let Some(body) = name
            .strip_prefix("reactive(")
            .and_then(|s| s.strip_suffix(')'))
        {
            let (mut up, mut down, mut cooldown_s) =
                match PoolPolicy::DEFAULT_REACTIVE {
                    PoolPolicy::Reactive { up, down, cooldown_s } => {
                        (up, down, cooldown_s)
                    }
                    _ => unreachable!(),
                };
            for (k, v) in params(body, "reactive")? {
                match k {
                    "up" => up = v,
                    "down" => down = v,
                    "cooldown" | "cooldown_s" => cooldown_s = v,
                    _ => anyhow::bail!("unknown reactive parameter `{k}`"),
                }
            }
            anyhow::ensure!(
                up > 0.0 && down >= 0.0 && down < up,
                "reactive needs 0 <= down < up"
            );
            anyhow::ensure!(cooldown_s >= 0.0, "cooldown must be >= 0");
            return Ok(PoolPolicy::Reactive { up, down, cooldown_s });
        }
        anyhow::bail!("unknown pool policy `{name}`")
    }
}

impl std::fmt::Display for PoolPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoolPolicy::Static => f.write_str("static"),
            PoolPolicy::Periodic { epoch_s, headroom } => {
                write!(f, "periodic(epoch={epoch_s},headroom={headroom})")
            }
            PoolPolicy::Reactive { up, down, cooldown_s } => {
                write!(f, "reactive(up={up},down={down},cooldown={cooldown_s})")
            }
        }
    }
}

/// Chunked-prefill iteration model (DESIGN.md §3.8): how much prefill
/// work a relaxed-pool iteration may fuse with its decode batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ChunkMode {
    /// Exclusive steps (the pre-§3.8 behaviour): an iteration is a whole
    /// prefill batch *or* a decode batch, never both. Kept as the
    /// differential baseline for the refactor.
    Off,
    /// Solver-chosen budget: each iteration takes the largest chunk that
    /// keeps its predicted latency inside the headroom-reduced TPOT budget
    /// (`PerfModel::chunk_budget`), floored at the minimum progress
    /// quantum.
    #[default]
    Auto,
    /// Fixed per-iteration chunk budget in tokens.
    Fixed(usize),
}

impl ChunkMode {
    pub fn is_enabled(self) -> bool {
        !matches!(self, ChunkMode::Off)
    }
}

impl std::str::FromStr for ChunkMode {
    type Err = anyhow::Error;

    /// Parse `off`, `auto`, or a fixed token count (`0` = off).
    fn from_str(name: &str) -> anyhow::Result<ChunkMode> {
        match name {
            "off" | "exclusive" => Ok(ChunkMode::Off),
            "auto" => Ok(ChunkMode::Auto),
            other => {
                let n: usize = other.parse().map_err(|_| {
                    anyhow::anyhow!(
                        "chunk_tokens must be `off`, `auto`, or a token \
                         count, got `{other}`"
                    )
                })?;
                Ok(if n == 0 {
                    ChunkMode::Off
                } else {
                    ChunkMode::Fixed(n)
                })
            }
        }
    }
}

impl std::fmt::Display for ChunkMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ChunkMode::Off => f.write_str("off"),
            ChunkMode::Auto => f.write_str("auto"),
            ChunkMode::Fixed(n) => write!(f, "{n}"),
        }
    }
}

/// Prefix-sharing KV cache configuration (DESIGN.md §3.7).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefixSpec {
    /// Resolve shared-prompt prefixes against per-instance block caches at
    /// admission, shortening prefill to the uncached remainder. Cached
    /// blocks are reclaimable capacity (LRU-evicted on demand), so turning
    /// this on never reduces admittable KV.
    pub enabled: bool,
}

impl Default for PrefixSpec {
    fn default() -> Self {
        PrefixSpec { enabled: true }
    }
}

impl PrefixSpec {
    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        Ok(PrefixSpec {
            enabled: v
                .get("enabled")
                .as_bool()
                .unwrap_or(Self::default().enabled),
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![("enabled", Json::Bool(self.enabled))])
    }
}

/// Online-request Service Level Objectives.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    /// Time-to-first-token bound (s).
    pub ttft: f64,
    /// Time-per-output-token bound (s) — the `S` in Algorithms 1 and 2.
    pub tpot: f64,
    /// Violation-rate threshold above which the system no longer provides
    /// valid online service (the paper uses 3%).
    pub violation_threshold: f64,
}

impl Default for SloSpec {
    fn default() -> Self {
        SloSpec {
            ttft: 5.0,
            tpot: 0.10,
            violation_threshold: 0.03,
        }
    }
}

impl SloSpec {
    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        Ok(SloSpec {
            ttft: v.req_f64("ttft")?,
            tpot: v.req_f64("tpot")?,
            violation_threshold: v
                .get("violation_threshold")
                .as_f64()
                .unwrap_or(0.03),
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ttft", Json::Num(self.ttft)),
            ("tpot", Json::Num(self.tpot)),
            ("violation_threshold", Json::Num(self.violation_threshold)),
        ])
    }
}

/// Scheduler tunables (§3.4). Defaults follow the paper's descriptions.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedulerParams {
    /// K — random-probe iterations in mix decoding selection (Alg. 2).
    pub mix_probe_iters: usize,
    /// Safety margin under the TPOT SLO kept when admitting offline work
    /// onto latency-strict nodes (fraction of S).
    pub slo_margin: f64,
    /// Token budget for one prefill iteration on a relaxed node.
    pub prefill_token_budget: usize,
    /// Max offline decode requests migrated per pull.
    pub migration_batch: usize,
    /// Offline gating: required benefit/cost ratio before prefilling new
    /// offline work (1.0 = paper's break-even rule).
    pub gating_benefit_ratio: f64,
    /// Estimated probability a resident offline request is evicted by a
    /// future online burst (input to the gating cost model).
    pub eviction_prob: f64,
    /// `online priority` baseline: fixed cap on decode batch size.
    pub baseline_decode_cap: usize,
}

impl Default for SchedulerParams {
    fn default() -> Self {
        SchedulerParams {
            mix_probe_iters: 8,
            slo_margin: 0.10,
            prefill_token_budget: 8192,
            migration_batch: 8,
            gating_benefit_ratio: 1.0,
            eviction_prob: 0.15,
            baseline_decode_cap: 96,
        }
    }
}

impl SchedulerParams {
    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let d = Self::default();
        Ok(SchedulerParams {
            mix_probe_iters: v
                .get("mix_probe_iters")
                .as_usize()
                .unwrap_or(d.mix_probe_iters),
            slo_margin: v.get("slo_margin").as_f64().unwrap_or(d.slo_margin),
            prefill_token_budget: v
                .get("prefill_token_budget")
                .as_usize()
                .unwrap_or(d.prefill_token_budget),
            migration_batch: v
                .get("migration_batch")
                .as_usize()
                .unwrap_or(d.migration_batch),
            gating_benefit_ratio: v
                .get("gating_benefit_ratio")
                .as_f64()
                .unwrap_or(d.gating_benefit_ratio),
            eviction_prob: v
                .get("eviction_prob")
                .as_f64()
                .unwrap_or(d.eviction_prob),
            baseline_decode_cap: v
                .get("baseline_decode_cap")
                .as_usize()
                .unwrap_or(d.baseline_decode_cap),
        })
    }
}

/// Cluster topology: counts of the two latency-constraint pools.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterSpec {
    /// Latency-relaxed instances (prefill + offline decode).
    pub relaxed_instances: usize,
    /// Latency-strict instances (online decode + mixed-in offline decode).
    pub strict_instances: usize,
}

impl Default for ClusterSpec {
    /// The paper evaluates with one of each.
    fn default() -> Self {
        ClusterSpec {
            relaxed_instances: 1,
            strict_instances: 1,
        }
    }
}

/// Top-level serving configuration bundle.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    pub model: ModelSpec,
    pub hardware: HardwareProfile,
    pub slo: SloSpec,
    pub sched: SchedulerParams,
    pub cluster: ClusterSpec,
    /// KV-transport link topology + fast-preemption configuration.
    pub transport: TransportSpec,
    /// Elastic pool-manager policy (DESIGN.md §3.6).
    pub pool: PoolPolicy,
    /// Prefix-sharing KV cache (DESIGN.md §3.7).
    pub prefix: PrefixSpec,
    /// Chunked-prefill iteration model (DESIGN.md §3.8).
    pub chunk_tokens: ChunkMode,
}

impl ServingConfig {
    pub fn preset_7b() -> Self {
        let hardware = HardwareProfile::ascend_910c();
        ServingConfig {
            model: ModelSpec::qwen2_5_7b(),
            transport: TransportSpec::for_hardware(&hardware),
            hardware,
            slo: SloSpec::default(),
            sched: SchedulerParams::default(),
            cluster: ClusterSpec::default(),
            pool: PoolPolicy::Static,
            prefix: PrefixSpec::default(),
            chunk_tokens: ChunkMode::Auto,
        }
    }

    pub fn preset_72b() -> Self {
        let hardware = HardwareProfile::ascend_910c();
        ServingConfig {
            model: ModelSpec::qwen2_5_72b(),
            transport: TransportSpec::for_hardware(&hardware),
            hardware,
            slo: SloSpec::default(),
            sched: SchedulerParams::default(),
            cluster: ClusterSpec::default(),
            pool: PoolPolicy::Static,
            prefix: PrefixSpec::default(),
            chunk_tokens: ChunkMode::Auto,
        }
    }

    /// Load from a JSON file; missing sections fall back to the 7B preset
    /// (transport defaults derive from the resolved hardware profile).
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Self> {
        let v = Json::parse_file(path)?;
        let base = Self::preset_7b();
        let hardware = match v.get("hardware") {
            Json::Null => base.hardware,
            Json::Str(s) => s.parse()?,
            h => HardwareProfile::from_json(h)?,
        };
        let transport_base = TransportSpec::for_hardware(&hardware);
        Ok(ServingConfig {
            model: match v.get("model") {
                Json::Null => base.model,
                Json::Str(s) => s.parse()?,
                m => ModelSpec::from_json(m)?,
            },
            transport: match v.get("transport") {
                Json::Null => transport_base,
                t => TransportSpec::from_json(t, &transport_base)?,
            },
            hardware,
            slo: match v.get("slo") {
                Json::Null => base.slo,
                s => SloSpec::from_json(s)?,
            },
            sched: match v.get("scheduler") {
                Json::Null => base.sched,
                s => SchedulerParams::from_json(s)?,
            },
            cluster: ClusterSpec {
                relaxed_instances: v
                    .get("cluster")
                    .get("relaxed_instances")
                    .as_usize()
                    .unwrap_or(1),
                strict_instances: v
                    .get("cluster")
                    .get("strict_instances")
                    .as_usize()
                    .unwrap_or(1),
            },
            pool: match v.get("pool_policy") {
                Json::Null => PoolPolicy::Static,
                Json::Str(s) => s.parse()?,
                other => anyhow::bail!(
                    "pool_policy must be a string (e.g. \
                     \"periodic(epoch=60,headroom=0.15)\"), got {other:?}"
                ),
            },
            prefix: match v.get("prefix") {
                Json::Null => PrefixSpec::default(),
                Json::Bool(b) => PrefixSpec { enabled: *b },
                p => PrefixSpec::from_json(p)?,
            },
            chunk_tokens: match v.get("chunk_tokens") {
                Json::Null => ChunkMode::Auto,
                Json::Str(s) => s.parse()?,
                Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => {
                    if *n == 0.0 {
                        ChunkMode::Off
                    } else {
                        ChunkMode::Fixed(*n as usize)
                    }
                }
                other => anyhow::bail!(
                    "chunk_tokens must be \"off\", \"auto\", or a whole \
                     token count, got {other:?}"
                ),
            },
        })
    }
}

/// Split a `k=v,k=v` parameter body into raw string pairs (shared by the
/// fleet/fault spec parsers; values are typed per key at the call site).
fn kv_pairs<'a>(
    body: &'a str,
    kind: &str,
) -> anyhow::Result<Vec<(&'a str, &'a str)>> {
    let mut out = Vec::new();
    for tok in body.split(',').filter(|t| !t.trim().is_empty()) {
        let (k, v) = tok.trim().split_once('=').ok_or_else(|| {
            anyhow::anyhow!("bad {kind} parameter `{tok}`")
        })?;
        out.push((k.trim(), v.trim()));
    }
    Ok(out)
}

/// Which pool of a replica a fault event targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPool {
    Relaxed,
    Strict,
}

impl std::str::FromStr for FaultPool {
    type Err = anyhow::Error;

    fn from_str(name: &str) -> anyhow::Result<FaultPool> {
        match name {
            "relaxed" => Ok(FaultPool::Relaxed),
            "strict" => Ok(FaultPool::Strict),
            other => anyhow::bail!("unknown fault pool `{other}`"),
        }
    }
}

impl std::fmt::Display for FaultPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FaultPool::Relaxed => "relaxed",
            FaultPool::Strict => "strict",
        })
    }
}

/// One scheduled instance crash (DESIGN.md §3.9): instance `inst` of
/// `pool` on fleet replica `replica` dies at `at`, recovers `down_s`
/// later, with `notice_s` of advance warning (0 = none) during which its
/// offline KV evacuates through the recoverable-eviction paths.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CrashEvent {
    pub at: f64,
    pub replica: usize,
    pub pool: FaultPool,
    pub inst: usize,
    pub down_s: f64,
    pub notice_s: f64,
}

impl CrashEvent {
    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        Ok(CrashEvent {
            at: v.req_f64("at")?,
            replica: v.get("replica").as_usize().unwrap_or(0),
            pool: match v.get("pool").as_str() {
                Some(s) => s.parse()?,
                None => FaultPool::Relaxed,
            },
            inst: v.get("inst").as_usize().unwrap_or(0),
            down_s: v.get("down_s").as_f64().unwrap_or(60.0),
            notice_s: v.get("notice_s").as_f64().unwrap_or(0.0),
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("at", Json::Num(self.at)),
            ("replica", Json::Num(self.replica as f64)),
            ("pool", Json::Str(self.pool.to_string())),
            ("inst", Json::Num(self.inst as f64)),
            ("down_s", Json::Num(self.down_s)),
            ("notice_s", Json::Num(self.notice_s)),
        ])
    }
}

impl std::fmt::Display for CrashEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "crash(at={},replica={},pool={},inst={},down={},notice={})",
            self.at, self.replica, self.pool, self.inst, self.down_s,
            self.notice_s
        )
    }
}

/// Stochastic crash process: per-instance exponential time between
/// failures with `mean_s` MTBF, `mttr_s` mean time to recover, and
/// `notice_s` of advance warning. Sampled from the run's seeded RNG, so
/// the fault schedule is part of the deterministic replay.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MtbfSpec {
    pub mean_s: f64,
    pub mttr_s: f64,
    pub notice_s: f64,
}

impl MtbfSpec {
    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        Ok(MtbfSpec {
            mean_s: v.req_f64("mean_s")?,
            mttr_s: v.get("mttr_s").as_f64().unwrap_or(60.0),
            notice_s: v.get("notice_s").as_f64().unwrap_or(0.0),
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mean_s", Json::Num(self.mean_s)),
            ("mttr_s", Json::Num(self.mttr_s)),
            ("notice_s", Json::Num(self.notice_s)),
        ])
    }
}

impl std::fmt::Display for MtbfSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mtbf(mean={},mttr={},notice={})",
            self.mean_s, self.mttr_s, self.notice_s
        )
    }
}

/// Fleet fault model (DESIGN.md §3.9): scheduled crash events plus an
/// optional stochastic MTBF process. `FaultSpec::none()` is the default —
/// and the differential guarantee: a zero-fault fleet behaves exactly
/// like the fault-free scheduler.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultSpec {
    pub crashes: Vec<CrashEvent>,
    pub mtbf: Option<MtbfSpec>,
}

impl FaultSpec {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_none(&self) -> bool {
        self.crashes.is_empty() && self.mtbf.is_none()
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let mut crashes = Vec::new();
        if let Json::Arr(items) = v.get("crashes") {
            for it in items {
                crashes.push(CrashEvent::from_json(it)?);
            }
        }
        Ok(FaultSpec {
            crashes,
            mtbf: match v.get("mtbf") {
                Json::Null => None,
                m => Some(MtbfSpec::from_json(m)?),
            },
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "crashes",
                Json::Arr(self.crashes.iter().map(|c| c.to_json()).collect()),
            ),
            (
                "mtbf",
                self.mtbf.as_ref().map_or(Json::Null, |m| m.to_json()),
            ),
        ])
    }
}

impl std::str::FromStr for FaultSpec {
    type Err = anyhow::Error;

    /// Parse `none`, or a `;`-separated list of
    /// `crash(at=300,replica=0,pool=relaxed,inst=0,down=60,notice=5)` and
    /// `mtbf(mean=600,mttr=60,notice=0)` terms (keys optional, any order;
    /// at most one `mtbf` term).
    fn from_str(name: &str) -> anyhow::Result<FaultSpec> {
        let name = name.trim();
        if name.is_empty() || name == "none" {
            return Ok(FaultSpec::none());
        }
        let mut spec = FaultSpec::none();
        for term in name.split(';').filter(|t| !t.trim().is_empty()) {
            let term = term.trim();
            if let Some(body) = term
                .strip_prefix("crash(")
                .and_then(|s| s.strip_suffix(')'))
            {
                let mut ev = CrashEvent {
                    at: f64::NAN,
                    replica: 0,
                    pool: FaultPool::Relaxed,
                    inst: 0,
                    down_s: 60.0,
                    notice_s: 0.0,
                };
                for (k, v) in kv_pairs(body, "crash")? {
                    match k {
                        "at" => ev.at = v.parse()?,
                        "replica" => ev.replica = v.parse()?,
                        "pool" => ev.pool = v.parse()?,
                        "inst" => ev.inst = v.parse()?,
                        "down" | "down_s" => ev.down_s = v.parse()?,
                        "notice" | "notice_s" => ev.notice_s = v.parse()?,
                        _ => anyhow::bail!("unknown crash parameter `{k}`"),
                    }
                }
                anyhow::ensure!(
                    ev.at.is_finite() && ev.at >= 0.0,
                    "crash needs at=<seconds>"
                );
                anyhow::ensure!(ev.down_s > 0.0, "down must be positive");
                anyhow::ensure!(ev.notice_s >= 0.0, "notice must be >= 0");
                spec.crashes.push(ev);
            } else if let Some(body) = term
                .strip_prefix("mtbf(")
                .and_then(|s| s.strip_suffix(')'))
            {
                anyhow::ensure!(
                    spec.mtbf.is_none(),
                    "at most one mtbf term"
                );
                let mut m = MtbfSpec {
                    mean_s: f64::NAN,
                    mttr_s: 60.0,
                    notice_s: 0.0,
                };
                for (k, v) in kv_pairs(body, "mtbf")? {
                    match k {
                        "mean" | "mean_s" => m.mean_s = v.parse()?,
                        "mttr" | "mttr_s" => m.mttr_s = v.parse()?,
                        "notice" | "notice_s" => m.notice_s = v.parse()?,
                        _ => anyhow::bail!("unknown mtbf parameter `{k}`"),
                    }
                }
                anyhow::ensure!(
                    m.mean_s.is_finite() && m.mean_s > 0.0,
                    "mtbf needs mean=<seconds> > 0"
                );
                anyhow::ensure!(m.mttr_s > 0.0, "mttr must be positive");
                anyhow::ensure!(m.notice_s >= 0.0, "notice must be >= 0");
                spec.mtbf = Some(m);
            } else {
                anyhow::bail!("unknown fault term `{term}`");
            }
        }
        Ok(spec)
    }
}

impl std::fmt::Display for FaultSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_none() {
            return f.write_str("none");
        }
        let mut first = true;
        for c in &self.crashes {
            if !first {
                f.write_str(";")?;
            }
            write!(f, "{c}")?;
            first = false;
        }
        if let Some(m) = &self.mtbf {
            if !first {
                f.write_str(";")?;
            }
            write!(f, "{m}")?;
        }
        Ok(())
    }
}

/// Fleet admission policy: how the top-level router picks a replica for
/// each arriving request (DESIGN.md §3.9).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutePolicy {
    /// Cycle through live replicas.
    RoundRobin,
    /// Least loaded (class-aware outstanding-work score) over all live
    /// replicas.
    #[default]
    LeastLoaded,
    /// Power-of-two-choices: sample two distinct live replicas from the
    /// seeded RNG, keep the less loaded — O(1) with near-least-loaded
    /// balance.
    PowerOfTwo,
}

impl std::str::FromStr for RoutePolicy {
    type Err = anyhow::Error;

    fn from_str(name: &str) -> anyhow::Result<RoutePolicy> {
        match name {
            "rr" | "round-robin" => Ok(RoutePolicy::RoundRobin),
            "least" | "least-loaded" => Ok(RoutePolicy::LeastLoaded),
            "p2c" | "power-of-two" => Ok(RoutePolicy::PowerOfTwo),
            other => anyhow::bail!("unknown route policy `{other}`"),
        }
    }
}

impl std::fmt::Display for RoutePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            RoutePolicy::RoundRobin => "rr",
            RoutePolicy::LeastLoaded => "least",
            RoutePolicy::PowerOfTwo => "p2c",
        })
    }
}

/// Fleet shape (DESIGN.md §3.9): how many replica groups (each a full
/// strict/relaxed cluster), the admission policy across them, and the
/// cross-replica offline work-stealing batch (0 = stealing off).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetSpec {
    pub replicas: usize,
    pub route: RoutePolicy,
    /// Max offline backlog entries a starved replica steals per pass.
    pub steal_batch: usize,
}

impl Default for FleetSpec {
    fn default() -> Self {
        FleetSpec {
            replicas: 1,
            route: RoutePolicy::LeastLoaded,
            steal_batch: 4,
        }
    }
}

impl FleetSpec {
    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let d = Self::default();
        Ok(FleetSpec {
            replicas: v.get("replicas").as_usize().unwrap_or(d.replicas),
            route: match v.get("route").as_str() {
                Some(s) => s.parse()?,
                None => d.route,
            },
            steal_batch: v
                .get("steal_batch")
                .as_usize()
                .unwrap_or(d.steal_batch),
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("replicas", Json::Num(self.replicas as f64)),
            ("route", Json::Str(self.route.to_string())),
            ("steal_batch", Json::Num(self.steal_batch as f64)),
        ])
    }
}

impl std::str::FromStr for FleetSpec {
    type Err = anyhow::Error;

    /// Parse `single` (one replica), a bare replica count, or
    /// `fleet(replicas=2,route=p2c,steal=4)` (keys optional, any order).
    fn from_str(name: &str) -> anyhow::Result<FleetSpec> {
        let name = name.trim();
        if name == "single" {
            return Ok(FleetSpec {
                replicas: 1,
                ..FleetSpec::default()
            });
        }
        if let Ok(n) = name.parse::<usize>() {
            anyhow::ensure!(n >= 1, "fleet needs at least one replica");
            return Ok(FleetSpec {
                replicas: n,
                ..FleetSpec::default()
            });
        }
        let Some(body) = name
            .strip_prefix("fleet(")
            .and_then(|s| s.strip_suffix(')'))
        else {
            anyhow::bail!("unknown fleet spec `{name}`");
        };
        let mut spec = FleetSpec::default();
        for (k, v) in kv_pairs(body, "fleet")? {
            match k {
                "replicas" => spec.replicas = v.parse()?,
                "route" => spec.route = v.parse()?,
                "steal" | "steal_batch" => spec.steal_batch = v.parse()?,
                _ => anyhow::bail!("unknown fleet parameter `{k}`"),
            }
        }
        anyhow::ensure!(
            spec.replicas >= 1,
            "fleet needs at least one replica"
        );
        Ok(spec)
    }
}

impl std::fmt::Display for FleetSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "fleet(replicas={},route={},steal={})",
            self.replicas, self.route, self.steal_batch
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_sane() {
        let m7 = ModelSpec::qwen2_5_7b();
        assert_eq!(m7.hidden, m7.q_heads * m7.head_dim);
        // Qwen2.5-7B has ~7.6B params
        let p = m7.param_count();
        assert!((6.5e9..8.5e9).contains(&p), "7b params {p}");

        let m72 = ModelSpec::qwen2_5_72b();
        assert_eq!(m72.hidden, m72.q_heads * m72.head_dim);
        let p = m72.param_count();
        assert!((6.5e10..8.5e10).contains(&p), "72b params {p}");
    }

    #[test]
    fn kv_bytes_per_token() {
        let m = ModelSpec::qwen2_5_7b();
        // 2 * 28 layers * 4 kv heads * 128 dim * 2 bytes = 57344
        assert_eq!(m.kv_bytes_per_token(), 57344.0);
    }

    #[test]
    fn parse_and_display_roundtrip() {
        assert_eq!(
            "7b".parse::<ModelSpec>().unwrap(),
            ModelSpec::qwen2_5_7b()
        );
        assert!("gpt-5".parse::<ModelSpec>().is_err());
        assert!("tpu-v9".parse::<HardwareProfile>().is_err());
        // Display emits the canonical name, which parses back to the preset.
        for name in ["qwen2.5-7b", "qwen2.5-72b", "tiny"] {
            let m: ModelSpec = name.parse().unwrap();
            assert_eq!(m.to_string(), name);
            assert_eq!(m.to_string().parse::<ModelSpec>().unwrap(), m);
        }
        for name in ["ascend-910c", "h800", "ascend-910c-vllm", "cpu-tiny"] {
            let h: HardwareProfile = name.parse().unwrap();
            assert_eq!(h.to_string(), name);
            assert_eq!(h.to_string().parse::<HardwareProfile>().unwrap(), h);
        }
    }

    #[test]
    fn transport_defaults_follow_hardware() {
        let t = TransportSpec::for_hardware(&HardwareProfile::ascend_910c());
        assert_eq!(t.pool.bandwidth, 25e9);
        assert_eq!(t.host.bandwidth, 50e9);
        assert_eq!(t.pool.sharing, LinkSharing::Fifo);
        assert!(t.recoverable_eviction && t.host_staging);
        assert_eq!(t.chunk_layers, 1);
        // JSON roundtrip.
        let base = t.clone();
        let t2 = TransportSpec::from_json(&t.to_json(), &base).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn pool_policy_parse_display_roundtrip() {
        assert_eq!("static".parse::<PoolPolicy>().unwrap(), PoolPolicy::Static);
        assert_eq!(
            "periodic".parse::<PoolPolicy>().unwrap(),
            PoolPolicy::DEFAULT_PERIODIC
        );
        assert_eq!(
            "reactive".parse::<PoolPolicy>().unwrap(),
            PoolPolicy::DEFAULT_REACTIVE
        );
        assert_eq!(
            "periodic(epoch=30,headroom=0.2)"
                .parse::<PoolPolicy>()
                .unwrap(),
            PoolPolicy::Periodic {
                epoch_s: 30.0,
                headroom: 0.2
            }
        );
        assert_eq!(
            "reactive(up=0.9,down=0.4,cooldown=10)"
                .parse::<PoolPolicy>()
                .unwrap(),
            PoolPolicy::Reactive {
                up: 0.9,
                down: 0.4,
                cooldown_s: 10.0
            }
        );
        // Display emits a form that parses back to the same value.
        for p in [
            PoolPolicy::Static,
            PoolPolicy::DEFAULT_PERIODIC,
            PoolPolicy::DEFAULT_REACTIVE,
            PoolPolicy::Periodic {
                epoch_s: 12.5,
                headroom: 0.25,
            },
        ] {
            assert_eq!(p.to_string().parse::<PoolPolicy>().unwrap(), p);
        }
        assert!("elastic".parse::<PoolPolicy>().is_err());
        assert!("periodic(epoch=0)".parse::<PoolPolicy>().is_err());
        assert!("periodic(warp=9)".parse::<PoolPolicy>().is_err());
        assert!("periodic(headroom=1.5)".parse::<PoolPolicy>().is_err());
        assert!("reactive(up=0.3,down=0.6)".parse::<PoolPolicy>().is_err());
        assert!("reactive(down=-1)".parse::<PoolPolicy>().is_err());
        assert!("reactive(cooldown=-30)".parse::<PoolPolicy>().is_err());
        assert!(PoolPolicy::DEFAULT_PERIODIC.is_elastic());
        assert!(!PoolPolicy::Static.is_elastic());
    }

    #[test]
    fn link_sharing_parses() {
        assert_eq!("fifo".parse::<LinkSharing>().unwrap(), LinkSharing::Fifo);
        assert_eq!(
            "fair-share".parse::<LinkSharing>().unwrap(),
            LinkSharing::FairShare
        );
        assert!("token-ring".parse::<LinkSharing>().is_err());
        assert_eq!(LinkSharing::FairShare.to_string(), "fair-share");
    }

    #[test]
    fn hardware_ratio_matches_table6_structure() {
        // H800 peak FLOPs ~3x one 910c chip (Table 6 rationale).
        let h = HardwareProfile::h800();
        let a = HardwareProfile::ascend_910c();
        let ratio = h.flops_gemm / a.flops_gemm;
        assert!((ratio - 3.0).abs() < 0.01, "ratio {ratio}");
        // vLLM-on-910c strictly slower than xLLM-on-910c.
        let v = HardwareProfile::ascend_910c_vllm();
        assert!(v.flops_gemm < a.flops_gemm);
        assert!(v.overhead_decode > a.overhead_decode);
    }

    #[test]
    fn model_json_roundtrip() {
        let m = ModelSpec::qwen2_5_7b();
        let j = m.to_json();
        let m2 = ModelSpec::from_json(&j).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn hardware_json_roundtrip() {
        let h = HardwareProfile::ascend_910c();
        let h2 = HardwareProfile::from_json(&h.to_json()).unwrap();
        assert_eq!(h, h2);
    }

    #[test]
    fn serving_config_from_file() {
        let dir = std::env::temp_dir().join("ooco_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(
            &path,
            r#"{
                "model": "72b",
                "hardware": "h800",
                "slo": {"ttft": 3.0, "tpot": 0.05},
                "scheduler": {"mix_probe_iters": 16},
                "cluster": {"relaxed_instances": 2, "strict_instances": 3},
                "pool_policy": "periodic(epoch=45,headroom=0.1)",
                "prefix": {"enabled": false},
                "transport": {
                    "chunk_layers": 4,
                    "recoverable_eviction": false,
                    "pool": {"bandwidth": 2e9, "sharing": "fair-share"}
                }
            }"#,
        )
        .unwrap();
        let cfg = ServingConfig::from_file(&path).unwrap();
        assert_eq!(cfg.model.name, "qwen2.5-72b");
        assert_eq!(cfg.hardware.name, "h800");
        assert_eq!(cfg.slo.tpot, 0.05);
        assert_eq!(cfg.slo.violation_threshold, 0.03); // default preserved
        assert_eq!(cfg.sched.mix_probe_iters, 16);
        assert_eq!(cfg.cluster.strict_instances, 3);
        assert_eq!(
            cfg.pool,
            PoolPolicy::Periodic {
                epoch_s: 45.0,
                headroom: 0.1
            }
        );
        assert_eq!(cfg.transport.chunk_layers, 4);
        assert!(!cfg.prefix.enabled);
        assert!(!cfg.transport.recoverable_eviction);
        assert!(cfg.transport.host_staging); // default preserved
        assert_eq!(cfg.transport.pool.bandwidth, 2e9);
        assert_eq!(cfg.transport.pool.sharing, LinkSharing::FairShare);
        // Absent host link falls back to the h800 hardware default.
        assert_eq!(cfg.transport.host.bandwidth, 2.0 * cfg.hardware.bw_comm);
    }

    #[test]
    fn serving_config_defaults() {
        let dir = std::env::temp_dir().join("ooco_cfg_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("empty.json");
        std::fs::write(&path, "{}").unwrap();
        let cfg = ServingConfig::from_file(&path).unwrap();
        assert_eq!(cfg.model.name, "qwen2.5-7b");
        assert_eq!(cfg.cluster.relaxed_instances, 1);
        assert_eq!(cfg.pool, PoolPolicy::Static);
        assert!(cfg.prefix.enabled); // cache defaults on
    }

    #[test]
    fn chunk_mode_parse_display_roundtrip() {
        assert_eq!("off".parse::<ChunkMode>().unwrap(), ChunkMode::Off);
        assert_eq!("auto".parse::<ChunkMode>().unwrap(), ChunkMode::Auto);
        assert_eq!(
            "2048".parse::<ChunkMode>().unwrap(),
            ChunkMode::Fixed(2048)
        );
        assert_eq!("0".parse::<ChunkMode>().unwrap(), ChunkMode::Off);
        assert!("sometimes".parse::<ChunkMode>().is_err());
        for m in [ChunkMode::Off, ChunkMode::Auto, ChunkMode::Fixed(512)] {
            assert_eq!(m.to_string().parse::<ChunkMode>().unwrap(), m);
        }
        assert!(ChunkMode::Auto.is_enabled());
        assert!(!ChunkMode::Off.is_enabled());
        assert_eq!(ChunkMode::default(), ChunkMode::Auto);
    }

    #[test]
    fn chunk_tokens_from_file() {
        let dir = std::env::temp_dir().join("ooco_cfg_chunk");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(&path, r#"{"chunk_tokens": "off"}"#).unwrap();
        let cfg = ServingConfig::from_file(&path).unwrap();
        assert_eq!(cfg.chunk_tokens, ChunkMode::Off);
        std::fs::write(&path, r#"{"chunk_tokens": 1024}"#).unwrap();
        let cfg = ServingConfig::from_file(&path).unwrap();
        assert_eq!(cfg.chunk_tokens, ChunkMode::Fixed(1024));
        // Fractional token counts are rejected, not truncated to 0.
        std::fs::write(&path, r#"{"chunk_tokens": 0.5}"#).unwrap();
        assert!(ServingConfig::from_file(&path).is_err());
        std::fs::write(&path, "{}").unwrap();
        let cfg = ServingConfig::from_file(&path).unwrap();
        assert_eq!(cfg.chunk_tokens, ChunkMode::Auto); // default on
    }

    #[test]
    fn fault_spec_parse_display_roundtrip() {
        assert_eq!("none".parse::<FaultSpec>().unwrap(), FaultSpec::none());
        assert_eq!("".parse::<FaultSpec>().unwrap(), FaultSpec::none());
        let s: FaultSpec =
            "crash(at=300,replica=1,pool=strict,inst=0,down=45,notice=5);\
             mtbf(mean=600,mttr=60,notice=2)"
                .parse()
                .unwrap();
        assert_eq!(
            s.crashes,
            vec![CrashEvent {
                at: 300.0,
                replica: 1,
                pool: FaultPool::Strict,
                inst: 0,
                down_s: 45.0,
                notice_s: 5.0,
            }]
        );
        assert_eq!(
            s.mtbf,
            Some(MtbfSpec {
                mean_s: 600.0,
                mttr_s: 60.0,
                notice_s: 2.0,
            })
        );
        // Defaults fill absent keys.
        let d: FaultSpec = "crash(at=10)".parse().unwrap();
        assert_eq!(d.crashes[0].pool, FaultPool::Relaxed);
        assert_eq!(d.crashes[0].down_s, 60.0);
        // Display emits a form that parses back to the same value.
        for spec in [FaultSpec::none(), s.clone(), d] {
            assert_eq!(
                spec.to_string().parse::<FaultSpec>().unwrap(),
                spec
            );
        }
        assert!("crash(down=60)".parse::<FaultSpec>().is_err()); // no at
        assert!("crash(at=10,down=0)".parse::<FaultSpec>().is_err());
        assert!("crash(at=10,pool=gpu)".parse::<FaultSpec>().is_err());
        assert!("mtbf(mttr=60)".parse::<FaultSpec>().is_err()); // no mean
        assert!("mtbf(mean=10);mtbf(mean=20)".parse::<FaultSpec>().is_err());
        assert!("meteor(at=10)".parse::<FaultSpec>().is_err());
    }

    #[test]
    fn fault_spec_json_roundtrip() {
        let s: FaultSpec =
            "crash(at=120,inst=1,notice=3);mtbf(mean=900,mttr=30)"
                .parse()
                .unwrap();
        assert_eq!(FaultSpec::from_json(&s.to_json()).unwrap(), s);
        let none = FaultSpec::none();
        assert_eq!(FaultSpec::from_json(&none.to_json()).unwrap(), none);
    }

    #[test]
    fn fleet_spec_parse_display_roundtrip() {
        assert_eq!(
            "single".parse::<FleetSpec>().unwrap(),
            FleetSpec {
                replicas: 1,
                ..FleetSpec::default()
            }
        );
        assert_eq!("3".parse::<FleetSpec>().unwrap().replicas, 3);
        let s: FleetSpec =
            "fleet(replicas=2,route=p2c,steal=8)".parse().unwrap();
        assert_eq!(
            s,
            FleetSpec {
                replicas: 2,
                route: RoutePolicy::PowerOfTwo,
                steal_batch: 8,
            }
        );
        for spec in [FleetSpec::default(), s] {
            assert_eq!(
                spec.to_string().parse::<FleetSpec>().unwrap(),
                spec
            );
        }
        assert!("0".parse::<FleetSpec>().is_err());
        assert!("fleet(replicas=0)".parse::<FleetSpec>().is_err());
        assert!("fleet(route=random)".parse::<FleetSpec>().is_err());
        assert!("armada(replicas=2)".parse::<FleetSpec>().is_err());
        for r in ["rr", "least", "p2c"] {
            let p: RoutePolicy = r.parse().unwrap();
            assert_eq!(p.to_string(), r);
        }
    }

    #[test]
    fn fleet_spec_json_roundtrip() {
        let s: FleetSpec = "fleet(replicas=4,route=rr,steal=0)"
            .parse()
            .unwrap();
        assert_eq!(FleetSpec::from_json(&s.to_json()).unwrap(), s);
    }

    #[test]
    fn prefix_spec_json_forms() {
        // Object form round-trips; bare-bool form is accepted in files.
        let p = PrefixSpec { enabled: false };
        assert_eq!(PrefixSpec::from_json(&p.to_json()).unwrap(), p);
        let dir = std::env::temp_dir().join("ooco_cfg_prefix");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(&path, r#"{"prefix": false}"#).unwrap();
        assert!(!ServingConfig::from_file(&path).unwrap().prefix.enabled);
    }
}
