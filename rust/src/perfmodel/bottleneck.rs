//! Performance-bottleneck analysis (paper §3.3.3).
//!
//! Classifies a decode iteration by which hardware resource limits it, and
//! computes `bs_sat` — the compute-saturated batch size threshold Algorithm 1
//! branches on.

use super::batch::BatchStats;
use super::roofline::PerfModel;

/// Which resource binds a decode iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bottleneck {
    /// GEMM compute saturated: growing the batch no longer improves
    /// efficiency; remaining headroom is memory capacity.
    Compute,
    /// Memory bandwidth (weight streaming / KV reads) dominates: batch can
    /// grow "for free" until compute saturation.
    MemoryBandwidth,
}

impl PerfModel {
    /// The compute-saturated decode batch size: the smallest batch size at
    /// which GEMM compute time catches up with GEMM memory time (paper:
    /// "when the Decode batch size is small ... GEMM latency remains
    /// relatively constant"; beyond saturation it scales with batch size).
    pub fn bs_sat(&self) -> usize {
        // Solve compute(n) >= memory(n) for the aggregated per-layer GEMMs.
        // Both sides are affine in n, so a closed form exists, but a simple
        // doubling+bisection keeps it robust to any parameter profile.
        let bound = |n: usize| {
            let c = self.decode_cost(BatchStats::new(n, n)); // kv≈0: GEMM only
            c.gemm.flops / self.hw_f_gemm() >= c.gemm.bytes / self.hw_m_gemm()
        };
        if bound(1) {
            return 1;
        }
        let mut hi = 2usize;
        while !bound(hi) {
            hi *= 2;
            if hi > 1 << 20 {
                return usize::MAX; // never saturates on this profile
            }
        }
        let mut lo = hi / 2;
        while lo + 1 < hi {
            let mid = (lo + hi) / 2;
            if bound(mid) {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        hi
    }

    /// Classify the bottleneck of a decode batch (Algorithm 1 line 3).
    pub fn decode_bottleneck(&self, batch: BatchStats) -> Bottleneck {
        if batch.size >= self.bs_sat() {
            Bottleneck::Compute
        } else {
            Bottleneck::MemoryBandwidth
        }
    }

    /// Fraction of instance KV capacity a batch consumes.
    pub fn memory_utilization(&self, batch: BatchStats) -> f64 {
        let cap = self.max_kv_tokens();
        if cap == 0 {
            return f64::INFINITY;
        }
        batch.total_kv_tokens as f64 / cap as f64
    }

    // Internal accessors (effective post-TP rates) used by bs_sat.
    fn hw_f_gemm(&self) -> f64 {
        let tp = self.model.tensor_parallel.max(1) as f64;
        let scale = if tp > 1.0 { tp * 0.92 } else { 1.0 };
        self.hw.flops_gemm * scale
    }

    fn hw_m_gemm(&self) -> f64 {
        let tp = self.model.tensor_parallel.max(1) as f64;
        let scale = if tp > 1.0 { tp * 0.92 } else { 1.0 };
        self.hw.bw_gemm * scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareProfile, ModelSpec};

    fn pm7b() -> PerfModel {
        PerfModel::new(ModelSpec::qwen2_5_7b(), HardwareProfile::ascend_910c())
    }

    #[test]
    fn bs_sat_in_plausible_range() {
        // Paper observes compute saturation around batch ~300 on the 910c;
        // with our achievable-rate profile the threshold lands in the same
        // order of magnitude.
        let sat = pm7b().bs_sat();
        assert!((50..600).contains(&sat), "bs_sat {sat}");
    }

    #[test]
    fn bs_sat_is_the_crossover() {
        let pm = pm7b();
        let sat = pm.bs_sat();
        assert_eq!(
            pm.decode_bottleneck(BatchStats::new(sat - 1, sat - 1)),
            Bottleneck::MemoryBandwidth
        );
        assert_eq!(
            pm.decode_bottleneck(BatchStats::new(sat, sat)),
            Bottleneck::Compute
        );
    }

    #[test]
    fn below_saturation_latency_nearly_flat() {
        let pm = pm7b();
        let sat = pm.bs_sat();
        // GEMM-latency growth from batch 1 to sat/2 is small (weight-bound).
        let short_kv = 64usize;
        let l1 = pm.decode_latency(BatchStats::new(1, short_kv));
        let lh = pm.decode_latency(BatchStats::new(sat / 2, sat / 2 * short_kv));
        assert!(lh < l1 * 2.0, "l1 {l1} lh {lh}");
        // Beyond saturation it scales ~linearly.
        let l2 = pm.decode_latency(BatchStats::new(2 * sat, 2 * sat * short_kv));
        let l4 = pm.decode_latency(BatchStats::new(4 * sat, 4 * sat * short_kv));
        assert!(l4 > 1.7 * l2, "l2 {l2} l4 {l4}");
    }

    #[test]
    fn memory_utilization() {
        let pm = pm7b();
        let cap = pm.max_kv_tokens();
        let u = pm.memory_utilization(BatchStats::new(10, cap / 2));
        assert!((u - 0.5).abs() < 0.01);
        assert_eq!(pm.memory_utilization(BatchStats::empty()), 0.0);
    }

    #[test]
    fn bs_sat_scales_with_bandwidth() {
        // More memory bandwidth -> saturation at smaller batch.
        let m = ModelSpec::qwen2_5_7b();
        let mut fast_mem = HardwareProfile::ascend_910c();
        fast_mem.bw_gemm *= 4.0;
        let sat_fast = PerfModel::new(m.clone(), fast_mem).bs_sat();
        let sat_base = PerfModel::new(m, HardwareProfile::ascend_910c()).bs_sat();
        assert!(sat_fast < sat_base);
    }
}
