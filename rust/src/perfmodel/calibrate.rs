//! Calibration of achievable-rate parameters from measured latencies.
//!
//! The paper obtains Table 4's achievable FLOPs/bandwidth "through a small
//! amount of profiling data". This module does the same for our substrate:
//! given measured `(batch-or-seq, latency)` samples from the real PJRT
//! engine, it fits the hardware profile's achievable rates and static
//! overheads by coordinate descent on mean absolute relative error — then
//! `bench_perfmodel_accuracy` replicates the paper's ~5% error claim on our
//! testbed.

use crate::config::{HardwareProfile, ModelSpec};

use super::batch::BatchStats;
use super::roofline::PerfModel;

/// One measured iteration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    pub kind: SampleKind,
    pub latency_s: f64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SampleKind {
    /// Single-request prefill with this prompt length.
    Prefill { prompt_len: usize },
    /// Decode iteration with these aggregates.
    Decode { batch: BatchStats },
}

/// Mean absolute relative error of a profile against samples.
pub fn mean_abs_rel_error(
    model: &ModelSpec,
    hw: &HardwareProfile,
    samples: &[Sample],
) -> f64 {
    let pm = PerfModel::new(model.clone(), hw.clone());
    let mut total = 0.0;
    for s in samples {
        let pred = match s.kind {
            SampleKind::Prefill { prompt_len } => pm.prefill_latency(prompt_len),
            SampleKind::Decode { batch } => pm.decode_latency(batch),
        };
        total += ((pred - s.latency_s) / s.latency_s).abs();
    }
    total / samples.len().max(1) as f64
}

/// Fit achievable rates + overheads by coordinate descent. Starts from
/// `initial`, multiplicatively perturbs one parameter at a time, keeps
/// improvements; converges in a few rounds for this smooth objective.
pub fn calibrate(
    model: &ModelSpec,
    initial: &HardwareProfile,
    samples: &[Sample],
    rounds: usize,
) -> HardwareProfile {
    let mut best = initial.clone();
    let mut best_err = mean_abs_rel_error(model, &best, samples);

    // (accessor, is_rate): rates are scaled, overheads too (both positive).
    let fields: &[fn(&mut HardwareProfile) -> &mut f64] = &[
        |h| &mut h.flops_gemm,
        |h| &mut h.flops_attn_prefill,
        |h| &mut h.flops_attn_decode,
        |h| &mut h.bw_gemm,
        |h| &mut h.bw_attn,
        |h| &mut h.overhead_prefill,
        |h| &mut h.overhead_decode,
    ];

    let mut step = 0.5; // +/-50% first round, shrinking
    for _ in 0..rounds {
        for field in fields {
            for factor in [1.0 + step, 1.0 / (1.0 + step)] {
                let mut cand = best.clone();
                *field(&mut cand) *= factor;
                let err = mean_abs_rel_error(model, &cand, samples);
                if err < best_err {
                    best_err = err;
                    best = cand;
                }
            }
        }
        step *= 0.6;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Generate samples from a known "ground truth" profile and check that
    /// calibration starting from a perturbed profile recovers low error.
    #[test]
    fn recovers_ground_truth_profile() {
        let model = ModelSpec::qwen2_5_7b();
        let truth = HardwareProfile::ascend_910c();
        let pm = PerfModel::new(model.clone(), truth.clone());

        let mut samples = Vec::new();
        for len in [64usize, 256, 1024, 2048, 4096] {
            samples.push(Sample {
                kind: SampleKind::Prefill { prompt_len: len },
                latency_s: pm.prefill_latency(len),
            });
        }
        for (n, kv) in [(1usize, 800usize), (8, 6_400), (64, 64_000), (256, 400_000)] {
            let b = BatchStats::new(n, kv);
            samples.push(Sample {
                kind: SampleKind::Decode { batch: b },
                latency_s: pm.decode_latency(b),
            });
        }

        // Start 2x off on every parameter.
        let mut start = truth.clone();
        start.flops_gemm *= 2.0;
        start.bw_gemm /= 2.0;
        start.flops_attn_decode *= 2.0;
        start.overhead_decode *= 3.0;

        let before = mean_abs_rel_error(&model, &start, &samples);
        let fitted = calibrate(&model, &start, &samples, 12);
        let after = mean_abs_rel_error(&model, &fitted, &samples);
        assert!(before > 0.2, "perturbed error should be large: {before}");
        assert!(after < 0.05, "calibrated error {after} (paper claims ~5%)");
    }

    #[test]
    fn error_zero_for_exact_profile() {
        let model = ModelSpec::qwen2_5_7b();
        let hw = HardwareProfile::ascend_910c();
        let pm = PerfModel::new(model.clone(), hw.clone());
        let samples = vec![Sample {
            kind: SampleKind::Decode {
                batch: BatchStats::new(10, 10_000),
            },
            latency_s: pm.decode_latency(BatchStats::new(10, 10_000)),
        }];
        assert!(mean_abs_rel_error(&model, &hw, &samples) < 1e-12);
    }

    #[test]
    fn empty_samples_no_panic() {
        let model = ModelSpec::tiny();
        let hw = HardwareProfile::cpu_tiny();
        assert_eq!(mean_abs_rel_error(&model, &hw, &[]), 0.0);
        let fitted = calibrate(&model, &hw, &[], 3);
        assert_eq!(fitted, hw);
    }
}
