//! Roofline latency model (paper §3.3.2, Eq. 1) over the operator costs.
//!
//! `PerfModel` binds a `ModelSpec` to a `HardwareProfile` and predicts the
//! latency, FLOPs and memory traffic of any Prefill or Decode iteration.
//! Decode-batch prediction is O(1) in the batch size: it only needs the
//! `(batch_size, total_kv_tokens)` aggregates carried by
//! [`BatchStats`](super::batch::BatchStats) — the property Algorithm 2's
//! binary search and the migration scheduler rely on (DESIGN.md §7).

use crate::config::{HardwareProfile, ModelSpec};

use super::batch::BatchStats;
use super::operators::{self, OpCost};

/// Cost breakdown of one iteration (a single model forward).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct IterCost {
    pub gemm: OpCost,
    pub attn: OpCost,
    /// Tensor-parallel collective time (s); 0 for TP=1.
    pub comm_s: f64,
    /// Static runtime overhead O_p / O_d (s).
    pub overhead_s: f64,
    /// Total predicted latency (s).
    pub latency_s: f64,
}

impl IterCost {
    pub fn total_flops(&self) -> f64 {
        self.gemm.flops + self.attn.flops
    }

    pub fn total_bytes(&self) -> f64 {
        self.gemm.bytes + self.attn.bytes
    }

    /// Achieved FLOP/s of the iteration — the y-axis of Fig. 3's roofline.
    pub fn achieved_flops(&self) -> f64 {
        if self.latency_s == 0.0 {
            0.0
        } else {
            self.total_flops() / self.latency_s
        }
    }

    /// Arithmetic intensity — the x-axis of Fig. 3's roofline.
    pub fn intensity(&self) -> f64 {
        if self.total_bytes() == 0.0 {
            0.0
        } else {
            self.total_flops() / self.total_bytes()
        }
    }
}

/// Eq. 1: `max(flops / F_a, bytes / M_a)`.
#[inline]
pub fn op_latency(cost: OpCost, flops_rate: f64, bw: f64) -> f64 {
    (cost.flops / flops_rate).max(cost.bytes / bw)
}

/// Intra-instance tensor-parallel interconnect (bytes/s) used for the
/// per-layer collectives when `tensor_parallel > 1`.
const TP_INTERCONNECT_BW: f64 = 200e9;
/// Parallelization efficiency of splitting one GEMM across TP chips.
const TP_EFFICIENCY: f64 = 0.92;

/// Roofline performance model for one (model, hardware) pair.
#[derive(Debug, Clone)]
pub struct PerfModel {
    pub model: ModelSpec,
    pub hw: HardwareProfile,
    /// Effective achievable rates after tensor-parallel scaling.
    f_gemm: f64,
    f_attn_prefill: f64,
    f_attn_decode: f64,
    m_gemm: f64,
    m_attn: f64,
    // Cached per-layer per-row GEMM costs (hot-path optimization: the decode
    // predictor runs inside Algorithm 2's inner loop).
    layer_gemm_unit: OpCost,
    layer_gemm_fixed: OpCost,
    lm_head_unit: OpCost,
    lm_head_fixed: OpCost,
}

impl PerfModel {
    pub fn new(model: ModelSpec, hw: HardwareProfile) -> Self {
        let tp = model.tensor_parallel.max(1) as f64;
        let scale = if tp > 1.0 { tp * TP_EFFICIENCY } else { 1.0 };
        // Decompose GEMM cost into N-proportional and fixed (weight) parts so
        // batch-latency prediction is O(1): cost(N) = fixed + N * unit.
        let unit = operators::layer_gemms(&model, 1.0);
        let two = operators::layer_gemms(&model, 2.0);
        let layer_gemm_unit = OpCost {
            flops: two.flops - unit.flops,
            bytes: two.bytes - unit.bytes,
        };
        let layer_gemm_fixed = OpCost {
            flops: unit.flops - layer_gemm_unit.flops,
            bytes: unit.bytes - layer_gemm_unit.bytes,
        };
        let lm1 = operators::lm_head(&model, 1.0);
        let lm2 = operators::lm_head(&model, 2.0);
        let lm_head_unit = OpCost {
            flops: lm2.flops - lm1.flops,
            bytes: lm2.bytes - lm1.bytes,
        };
        let lm_head_fixed = OpCost {
            flops: lm1.flops - lm_head_unit.flops,
            bytes: lm1.bytes - lm_head_unit.bytes,
        };
        PerfModel {
            f_gemm: hw.flops_gemm * scale,
            f_attn_prefill: hw.flops_attn_prefill * scale,
            f_attn_decode: hw.flops_attn_decode * scale,
            m_gemm: hw.bw_gemm * scale,
            m_attn: hw.bw_attn * scale,
            model,
            hw,
            layer_gemm_unit,
            layer_gemm_fixed,
            lm_head_unit,
            lm_head_fixed,
        }
    }

    fn tp_comm_s(&self, n_rows: f64) -> f64 {
        let tp = self.model.tensor_parallel;
        if tp <= 1 {
            return 0.0;
        }
        // Two all-reduces per layer (after attention and after MLP), ring
        // style: each chip moves ~2·(tp-1)/tp of the activation bytes.
        let act_bytes = n_rows * self.model.hidden as f64 * self.model.bytes_per_value;
        let per_layer =
            2.0 * act_bytes * 2.0 * (tp as f64 - 1.0) / tp as f64 / TP_INTERCONNECT_BW;
        per_layer * self.model.layers as f64
    }

    /// Latency of one prefill iteration over requests with the given prompt
    /// lengths (batched prefill: GEMMs see the total token count, attention
    /// runs per request).
    pub fn prefill_cost(&self, prompt_lens: &[usize]) -> IterCost {
        let total: f64 = prompt_lens.iter().map(|&s| s as f64).sum();
        let l = self.model.layers as f64;
        let gemm = operators::layer_gemms(&self.model, total)
            .scale(l)
            .add(operators::lm_head(&self.model, prompt_lens.len() as f64));
        let mut attn = OpCost::default();
        for &s in prompt_lens {
            attn = attn.add(operators::attention(&self.model, s as f64, s as f64));
        }
        attn = attn.scale(l);
        let comm_s = self.tp_comm_s(total);
        let latency_s = op_latency(gemm, self.f_gemm, self.m_gemm)
            + op_latency(attn, self.f_attn_prefill, self.m_attn)
            + comm_s
            + self.hw.overhead_prefill;
        IterCost {
            gemm,
            attn,
            comm_s,
            overhead_s: self.hw.overhead_prefill,
            latency_s,
        }
    }

    /// Convenience: single-request prefill latency (s).
    pub fn prefill_latency(&self, prompt_len: usize) -> f64 {
        self.prefill_cost(&[prompt_len]).latency_s
    }

    /// Full cost breakdown of one decode iteration described by aggregates.
    pub fn decode_cost(&self, batch: BatchStats) -> IterCost {
        let n = batch.size as f64;
        if batch.size == 0 {
            return IterCost::default();
        }
        let l = self.model.layers as f64;
        let gemm = OpCost {
            flops: (self.layer_gemm_fixed.flops + n * self.layer_gemm_unit.flops) * l
                + self.lm_head_fixed.flops
                + n * self.lm_head_unit.flops,
            bytes: (self.layer_gemm_fixed.bytes + n * self.layer_gemm_unit.bytes) * l
                + self.lm_head_fixed.bytes
                + n * self.lm_head_unit.bytes,
        };
        // Batched decode attention: flops/bytes are linear in the aggregates.
        let d_h = (self.model.q_heads * self.model.head_dim) as f64;
        let d_kv = (self.model.kv_heads * self.model.head_dim) as f64;
        let d = self.model.bytes_per_value;
        let tkv = batch.total_kv_tokens as f64;
        let attn = OpCost {
            flops: 4.0 * d_h * tkv * l,
            bytes: d * (2.0 * n * d_h + 2.0 * tkv * d_kv) * l,
        };
        let comm_s = self.tp_comm_s(n);
        let latency_s = op_latency(gemm, self.f_gemm, self.m_gemm)
            + op_latency(attn, self.f_attn_decode, self.m_attn)
            + comm_s
            + self.hw.overhead_decode;
        IterCost {
            gemm,
            attn,
            comm_s,
            overhead_s: self.hw.overhead_decode,
            latency_s,
        }
    }

    /// O(1) decode-iteration latency from batch aggregates — the predictor
    /// `L(·)` in Algorithms 1 and 2.
    #[inline]
    pub fn decode_latency(&self, batch: BatchStats) -> f64 {
        if batch.size == 0 {
            return 0.0;
        }
        let n = batch.size as f64;
        let l = self.model.layers as f64;
        let gemm_flops = (self.layer_gemm_fixed.flops + n * self.layer_gemm_unit.flops)
            * l
            + self.lm_head_fixed.flops
            + n * self.lm_head_unit.flops;
        let gemm_bytes = (self.layer_gemm_fixed.bytes + n * self.layer_gemm_unit.bytes)
            * l
            + self.lm_head_fixed.bytes
            + n * self.lm_head_unit.bytes;
        let d_h = (self.model.q_heads * self.model.head_dim) as f64;
        let d_kv = (self.model.kv_heads * self.model.head_dim) as f64;
        let d = self.model.bytes_per_value;
        let tkv = batch.total_kv_tokens as f64;
        let attn_flops = 4.0 * d_h * tkv * l;
        let attn_bytes = d * (2.0 * n * d_h + 2.0 * tkv * d_kv) * l;
        (gemm_flops / self.f_gemm).max(gemm_bytes / self.m_gemm)
            + (attn_flops / self.f_attn_decode).max(attn_bytes / self.m_attn)
            + self.tp_comm_s(n)
            + self.hw.overhead_decode
    }

    /// Roofline cost of one *composed* iteration (DESIGN.md §3.8): a
    /// decode batch described by `decode` fused with `prefill_tokens` of
    /// chunked prefill work in the same model forward. GEMMs see the
    /// combined row count; attention splits into the decode aggregate part
    /// (decode achievable rates) and the chunk part (prefill achievable
    /// rates, priced at chunk-local context — the documented
    /// approximation: a chunk deep into a long prompt reads more context
    /// than this model charges). With `prefill_tokens == 0` this is
    /// *exactly* [`PerfModel::decode_cost`], which keeps the elastic
    /// planner's pure-decode sizing byte-identical when chunking is off.
    pub fn mixed_iter_cost(
        &self,
        decode: BatchStats,
        prefill_tokens: usize,
    ) -> IterCost {
        if prefill_tokens == 0 {
            return self.decode_cost(decode);
        }
        let p = prefill_tokens as f64;
        let l = self.model.layers as f64;
        let n = decode.size as f64;
        // GEMM rows: every decode query token plus every prefill token;
        // the LM head samples one row per decode participant plus the
        // chunk's boundary token.
        let rows = n + p;
        let head_rows = n + 1.0;
        let gemm = OpCost {
            flops: (self.layer_gemm_fixed.flops + rows * self.layer_gemm_unit.flops)
                * l
                + self.lm_head_fixed.flops
                + head_rows * self.lm_head_unit.flops,
            bytes: (self.layer_gemm_fixed.bytes + rows * self.layer_gemm_unit.bytes)
                * l
                + self.lm_head_fixed.bytes
                + head_rows * self.lm_head_unit.bytes,
        };
        // Decode attention over the batch aggregates (decode rates).
        let d_h = (self.model.q_heads * self.model.head_dim) as f64;
        let d_kv = (self.model.kv_heads * self.model.head_dim) as f64;
        let d = self.model.bytes_per_value;
        let tkv = decode.total_kv_tokens as f64;
        let dec_attn = OpCost {
            flops: 4.0 * d_h * tkv * l,
            bytes: d * (2.0 * n * d_h + 2.0 * tkv * d_kv) * l,
        };
        // Chunk attention over its own span (prefill rates).
        let pre_attn = operators::attention(&self.model, p, p).scale(l);
        let comm_s = self.tp_comm_s(rows);
        let latency_s = op_latency(gemm, self.f_gemm, self.m_gemm)
            + op_latency(dec_attn, self.f_attn_decode, self.m_attn)
            + op_latency(pre_attn, self.f_attn_prefill, self.m_attn)
            + comm_s
            + self.hw.overhead_prefill.max(self.hw.overhead_decode);
        IterCost {
            gemm,
            attn: dec_attn.add(pre_attn),
            comm_s,
            overhead_s: self.hw.overhead_prefill.max(self.hw.overhead_decode),
            latency_s,
        }
    }

    /// Chunk-budget solver (DESIGN.md §3.8): the largest prefill-token
    /// chunk that keeps the composed iteration's predicted latency within
    /// `latency_budget`, capped at `max_tokens`. Returns 0 when even the
    /// pure-decode iteration misses the budget (callers apply a minimum
    /// progress quantum). Binary search over the monotone latency.
    pub fn chunk_budget(
        &self,
        decode: BatchStats,
        latency_budget: f64,
        max_tokens: usize,
    ) -> usize {
        if max_tokens == 0
            || self.mixed_iter_cost(decode, 1).latency_s > latency_budget
        {
            return 0;
        }
        let fits = |b: usize| {
            self.mixed_iter_cost(decode, b).latency_s <= latency_budget
        };
        let (mut lo, mut hi) = (1usize, max_tokens);
        if fits(hi) {
            return hi;
        }
        // Invariant: fits(lo), !fits(hi).
        while lo + 1 < hi {
            let mid = lo + (hi - lo) / 2;
            if fits(mid) {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// Contention-free KV-cache transfer latency between instances over the
    /// profile's `B_c`. Scheduling no longer uses this directly — the
    /// `transport` subsystem models links, queuing, and chunking — but it
    /// stays as the analytic reference: an idle link with zero per-chunk
    /// setup latency matches it exactly (asserted in
    /// `tests/transport_properties.rs`); the default link adds
    /// `chunks x LinkSpec::latency` of setup time on top.
    pub fn kv_transfer_latency(&self, tokens: usize) -> f64 {
        tokens as f64 * self.model.kv_bytes_per_token() / self.hw.bw_comm
    }

    /// Per-layer share of a prefill iteration — the layer-level interruption
    /// granularity of §3.4.1's preemption mechanism.
    pub fn prefill_layer_latency(&self, prompt_len: usize) -> f64 {
        self.prefill_latency(prompt_len) / self.model.layers as f64
    }

    /// Maximum KV-cache tokens one instance can hold
    /// (capacity − weights − 5% activation reserve).
    pub fn max_kv_tokens(&self) -> usize {
        let tp = self.model.tensor_parallel.max(1) as f64;
        let capacity = self.hw.mem_capacity * tp * 0.95;
        let free = capacity - self.model.weights_bytes();
        if free <= 0.0 {
            return 0;
        }
        (free / self.model.kv_bytes_per_token()) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareProfile, ModelSpec};

    fn pm7b() -> PerfModel {
        PerfModel::new(ModelSpec::qwen2_5_7b(), HardwareProfile::ascend_910c())
    }

    #[test]
    fn decode_latency_realistic_range() {
        let pm = pm7b();
        // Small batch: dominated by weight streaming + overhead, ~10-25 ms.
        let lat = pm.decode_latency(BatchStats::new(1, 500));
        assert!((0.005..0.04).contains(&lat), "1x500 lat {lat}");
        // Production-size batch stays under a 100 ms TPOT bound.
        let lat = pm.decode_latency(BatchStats::new(100, 100 * 1000));
        assert!((0.01..0.1).contains(&lat), "100x1000 lat {lat}");
        // Huge batch with long contexts exceeds it.
        let lat = pm.decode_latency(BatchStats::new(800, 800 * 2500));
        assert!(lat > 0.1, "800x2500 lat {lat}");
    }

    #[test]
    fn prefill_latency_realistic_range() {
        let pm = pm7b();
        let lat = pm.prefill_latency(1892); // OOC online mean prompt
        assert!((0.05..0.5).contains(&lat), "prefill lat {lat}");
        // Longer prompts cost superlinearly more (attention s^2 term).
        let l1 = pm.prefill_latency(1000);
        let l4 = pm.prefill_latency(4000);
        assert!(l4 > 3.5 * l1, "l1={l1} l4={l4}");
    }

    #[test]
    fn decode_latency_monotone_in_batch_and_kv() {
        let pm = pm7b();
        let base = pm.decode_latency(BatchStats::new(10, 10_000));
        assert!(pm.decode_latency(BatchStats::new(11, 11_000)) >= base);
        assert!(pm.decode_latency(BatchStats::new(10, 20_000)) > base);
        // More batch at same total KV also costs more GEMM rows.
        assert!(pm.decode_latency(BatchStats::new(20, 10_000)) > base);
    }

    #[test]
    fn decode_latency_matches_cost_breakdown() {
        let pm = pm7b();
        for (n, tkv) in [(1usize, 100usize), (32, 32_000), (300, 500_000)] {
            let b = BatchStats::new(n, tkv);
            let fast = pm.decode_latency(b);
            let full = pm.decode_cost(b).latency_s;
            assert!(
                (fast - full).abs() < 1e-12,
                "fast {fast} vs full {full}"
            );
        }
    }

    #[test]
    fn empty_batch_is_free() {
        let pm = pm7b();
        assert_eq!(pm.decode_latency(BatchStats::new(0, 0)), 0.0);
        assert_eq!(pm.decode_cost(BatchStats::new(0, 0)).latency_s, 0.0);
    }

    #[test]
    fn small_batch_decode_memory_bound_large_compute_heavy() {
        let pm = pm7b();
        // Batch 1 decode: GEMM time dominated by weight reads, not FLOPs.
        let c = pm.decode_cost(BatchStats::new(1, 500));
        assert!(c.gemm.bytes / pm.m_gemm > c.gemm.flops / pm.f_gemm);
        // Batch 1000: compute side dominates.
        let c = pm.decode_cost(BatchStats::new(1000, 1000 * 200));
        assert!(c.gemm.flops / pm.f_gemm > c.gemm.bytes / pm.m_gemm);
    }

    #[test]
    fn prefill_compute_saturated_beyond_short_lengths() {
        let pm = pm7b();
        // Long prefill is compute-bound (paper: beyond ~250-300 tokens).
        let c = pm.prefill_cost(&[2000]);
        assert!(c.gemm.flops / pm.f_gemm > c.gemm.bytes / pm.m_gemm);
        // Very short prefill is not.
        let c = pm.prefill_cost(&[16]);
        assert!(c.gemm.flops / pm.f_gemm < c.gemm.bytes / pm.m_gemm);
    }

    #[test]
    fn kv_capacity_7b_vs_72b() {
        let pm7 = pm7b();
        let cap7 = pm7.max_kv_tokens();
        // ~48 GB free / 57 KB per token => several hundred thousand tokens.
        assert!((400_000..1_200_000).contains(&cap7), "cap7 {cap7}");
        let pm72 = PerfModel::new(
            ModelSpec::qwen2_5_72b(),
            HardwareProfile::ascend_910c(),
        );
        let cap72 = pm72.max_kv_tokens();
        assert!(cap72 > 0, "72B TP=4 must fit");
        assert!(cap72 < cap7, "72B holds fewer KV tokens than 7B");
    }

    #[test]
    fn tp_adds_comm_but_scales_compute() {
        let m1 = ModelSpec::qwen2_5_72b();
        let mut m_tp1 = m1.clone();
        m_tp1.tensor_parallel = 1;
        let pm_tp4 = PerfModel::new(m1, HardwareProfile::ascend_910c());
        let pm_tp1 = PerfModel::new(m_tp1, HardwareProfile::ascend_910c());
        let b = BatchStats::new(64, 64_000);
        assert!(pm_tp4.decode_latency(b) < pm_tp1.decode_latency(b));
        assert!(pm_tp4.decode_cost(b).comm_s > 0.0);
        assert_eq!(pm_tp1.decode_cost(b).comm_s, 0.0);
    }

    #[test]
    fn kv_transfer_latency_scales() {
        let pm = pm7b();
        let t1 = pm.kv_transfer_latency(1000);
        let t2 = pm.kv_transfer_latency(2000);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
        // 1892-token prompt over 25 GB/s RDMA: a few ms.
        let t = pm.kv_transfer_latency(1892);
        assert!((0.001..0.02).contains(&t), "transfer {t}");
    }

    #[test]
    fn layer_interruption_granularity_tens_of_ms() {
        let pm = pm7b();
        // Paper §3.4.1: layer-level preemption lands within tens of ms.
        let per_layer = pm.prefill_layer_latency(4000);
        assert!(per_layer < 0.05, "per-layer {per_layer}");
    }

    #[test]
    fn mixed_iter_cost_degenerates_to_decode() {
        let pm = pm7b();
        for (n, tkv) in [(0usize, 0usize), (1, 500), (64, 64_000)] {
            let b = BatchStats::new(n, tkv);
            let mixed = pm.mixed_iter_cost(b, 0).latency_s;
            let pure = pm.decode_cost(b).latency_s;
            assert!(
                (mixed - pure).abs() < 1e-15,
                "mixed(b,0) {mixed} != decode {pure}"
            );
        }
    }

    #[test]
    fn mixed_iter_cost_monotone_in_chunk() {
        let pm = pm7b();
        let b = BatchStats::new(20, 30_000);
        let mut last = pm.mixed_iter_cost(b, 0).latency_s;
        for p in [1usize, 64, 256, 1024, 4096, 16384] {
            let lat = pm.mixed_iter_cost(b, p).latency_s;
            assert!(lat >= last, "chunk {p}: {lat} < {last}");
            last = lat;
        }
        // And monotone in the decode side too.
        let small = pm.mixed_iter_cost(BatchStats::new(5, 5_000), 512);
        let big = pm.mixed_iter_cost(BatchStats::new(50, 100_000), 512);
        assert!(big.latency_s > small.latency_s);
    }

    #[test]
    fn chunk_budget_maximal_under_bound() {
        let pm = pm7b();
        let b = BatchStats::new(10, 15_000);
        let budget = 0.09;
        let chunk = pm.chunk_budget(b, budget, 8192);
        assert!(chunk > 0, "90 ms must fit some prefill over a small batch");
        assert!(
            pm.mixed_iter_cost(b, chunk).latency_s <= budget,
            "solver answer misses its own budget"
        );
        if chunk < 8192 {
            assert!(
                pm.mixed_iter_cost(b, chunk + 1).latency_s > budget,
                "chunk {chunk} is not maximal"
            );
        }
        // A decode batch already over the bound leaves no chunk room.
        let heavy = BatchStats::new(900, 900 * 2500);
        assert!(pm.decode_latency(heavy) > budget);
        assert_eq!(pm.chunk_budget(heavy, budget, 8192), 0);
        // Huge budget saturates at the cap.
        assert_eq!(pm.chunk_budget(b, 10.0, 8192), 8192);
    }

    #[test]
    fn roofline_points_consistent() {
        let pm = pm7b();
        let c = pm.decode_cost(BatchStats::new(200, 200 * 800));
        assert!(c.achieved_flops() > 0.0);
        assert!(c.achieved_flops() <= pm.hw.flops_gemm * 1.001);
        assert!(c.intensity() > 0.0);
    }
}
