//! Operator-level compute/memory accounting (paper Table 3).
//!
//! For computation we use theoretical FLOP counts; for memory traffic we
//! assume operators effectively use on-chip cache/buffers (the PRoof-style
//! assumption the paper adopts) and count only the required input/output
//! tensor bytes. The fused flash-attention kernel is modeled as a single
//! operator so its intermediate score matrix generates no HBM traffic.
//!
//! Note on Table 3's attention-memory row: we account K/V bytes physically
//! as `2·d·S_kv·H_kv·Dh` (GQA caches only `H_kv` heads). The paper's printed
//! formula (`S_kv·D_h·H_q/H_kv`) reads as a typo for this same quantity —
//! with it, GQA would *increase* KV traffic, contradicting §2.3's statement
//! that MQA/GQA/MLA significantly reduce KV-cache size.

use crate::config::ModelSpec;

/// FLOPs + bytes for one operator instance.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct OpCost {
    pub flops: f64,
    pub bytes: f64,
}

impl OpCost {
    pub fn add(self, other: OpCost) -> OpCost {
        OpCost {
            flops: self.flops + other.flops,
            bytes: self.bytes + other.bytes,
        }
    }

    pub fn scale(self, k: f64) -> OpCost {
        OpCost {
            flops: self.flops * k,
            bytes: self.bytes * k,
        }
    }

    /// Arithmetic intensity (FLOPs per byte) — x-axis of the roofline chart.
    pub fn intensity(&self) -> f64 {
        if self.bytes == 0.0 {
            0.0
        } else {
            self.flops / self.bytes
        }
    }
}

/// GEMM: `[N, Din] x [Din, Dout]`.
/// Compute `2·N·Din·Dout`; memory `d·(N·Din + Din·Dout + N·Dout)`.
pub fn gemm(n: f64, d_in: f64, d_out: f64, d: f64) -> OpCost {
    OpCost {
        flops: 2.0 * n * d_in * d_out,
        bytes: d * (n * d_in + d_in * d_out + n * d_out),
    }
}

/// Fused attention for one request: Q of `s_q` tokens against `s_kv` cached
/// tokens. Compute `4·D_h·S_q·S_kv` (QK^T + PV) with `D_h = H_q·Dh`;
/// memory = Q + output + K + V tensor bytes.
pub fn attention(ms: &ModelSpec, s_q: f64, s_kv: f64) -> OpCost {
    let d = ms.bytes_per_value;
    let d_h = (ms.q_heads * ms.head_dim) as f64;
    let d_kv = (ms.kv_heads * ms.head_dim) as f64;
    OpCost {
        flops: 4.0 * d_h * s_q * s_kv,
        bytes: d * (2.0 * s_q * d_h + 2.0 * s_kv * d_kv),
    }
}

/// All GEMM work in one transformer layer with `n` token rows
/// (qkv + output projection + SwiGLU gate/up/down).
pub fn layer_gemms(ms: &ModelSpec, n: f64) -> OpCost {
    let d = ms.bytes_per_value;
    let h = ms.hidden as f64;
    let qkv_out = ((ms.q_heads + 2 * ms.kv_heads) * ms.head_dim) as f64;
    let ffn = ms.ffn as f64;
    gemm(n, h, qkv_out, d)
        .add(gemm(n, h, h, d)) // output projection
        .add(gemm(n, h, ffn, d)) // gate
        .add(gemm(n, h, ffn, d)) // up
        .add(gemm(n, ffn, h, d)) // down
}

/// LM-head GEMM for `n` output rows.
pub fn lm_head(ms: &ModelSpec, n: f64) -> OpCost {
    gemm(n, ms.hidden as f64, ms.vocab as f64, ms.bytes_per_value)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_formula() {
        let c = gemm(10.0, 100.0, 200.0, 2.0);
        assert_eq!(c.flops, 2.0 * 10.0 * 100.0 * 200.0);
        assert_eq!(c.bytes, 2.0 * (10.0 * 100.0 + 100.0 * 200.0 + 10.0 * 200.0));
    }

    #[test]
    fn attention_decode_vs_prefill() {
        let ms = ModelSpec::qwen2_5_7b();
        // Decode: one query token against 1000 cached tokens.
        let dec = attention(&ms, 1.0, 1000.0);
        // Prefill of the same 1000 tokens.
        let pre = attention(&ms, 1000.0, 1000.0);
        assert!(pre.flops > dec.flops * 500.0);
        // Decode attention is far less compute-intense than prefill attention.
        assert!(dec.intensity() < pre.intensity() / 100.0);
    }

    #[test]
    fn gqa_reduces_kv_bytes() {
        let mut mha = ModelSpec::qwen2_5_7b();
        mha.kv_heads = mha.q_heads; // pretend MHA
        let gqa = ModelSpec::qwen2_5_7b();
        let b_mha = attention(&mha, 1.0, 1000.0).bytes;
        let b_gqa = attention(&gqa, 1.0, 1000.0).bytes;
        assert!(b_gqa < b_mha, "GQA must reduce attention memory traffic");
    }

    #[test]
    fn layer_gemm_flops_match_param_estimate() {
        let ms = ModelSpec::qwen2_5_7b();
        // Per-layer GEMM FLOPs for one token ~= 2 * (per-layer matmul params)
        let per_layer = layer_gemms(&ms, 1.0).flops;
        let h = ms.hidden as f64;
        let params = h * h
            + 2.0 * h * (ms.kv_heads * ms.head_dim) as f64
            + h * h
            + 3.0 * h * ms.ffn as f64;
        assert!((per_layer / (2.0 * params) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn opcost_algebra() {
        let a = OpCost { flops: 1.0, bytes: 2.0 };
        let b = OpCost { flops: 3.0, bytes: 4.0 };
        let s = a.add(b).scale(2.0);
        assert_eq!(s, OpCost { flops: 8.0, bytes: 12.0 });
        assert_eq!(OpCost::default().intensity(), 0.0);
    }
}
