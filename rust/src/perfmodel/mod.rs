//! Roofline-based LLM inference performance model (paper §3.3).
//!
//! An operator-level behavioral simulator for decoder-only transformers:
//! - [`operators`] — FLOPs/bytes per GEMM and fused-attention op (Table 3);
//! - [`roofline`] — Eq. 1 latency prediction with Table 4's achievable-rate
//!   parameters, O(1) in the decode batch via [`batch::BatchStats`];
//! - [`bottleneck`] — compute/memory-bandwidth classification and the
//!   `bs_sat` threshold Algorithm 1 branches on (§3.3.3);
//! - [`calibrate`] — fits achievable rates from profiled samples, as the
//!   paper does for Table 4.

pub mod batch;
pub mod bottleneck;
pub mod calibrate;
pub mod operators;
pub mod roofline;

pub use batch::{BatchStats, PrefixSums};
pub use bottleneck::Bottleneck;
pub use calibrate::{calibrate, mean_abs_rel_error, Sample, SampleKind};
pub use operators::OpCost;
pub use roofline::{IterCost, PerfModel};
