//! O(1) decode-batch aggregates.
//!
//! The latency predictor only depends on `(batch_size, total_kv_tokens)`
//! (see `roofline.rs`), so schedulers carry this tiny value type instead of
//! walking request lists. `with`/`without` make Algorithm 2's
//! `L(B ∪ {r})` probes allocation-free, and `PrefixSums` supports its
//! binary-search step over length-sorted candidates.

/// Aggregates describing one decode iteration's batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct BatchStats {
    /// Number of requests in the batch (each contributes one query token).
    pub size: usize,
    /// Sum over requests of their current KV length (attention tokens read).
    pub total_kv_tokens: usize,
}

impl BatchStats {
    pub fn new(size: usize, total_kv_tokens: usize) -> Self {
        BatchStats {
            size,
            total_kv_tokens,
        }
    }

    pub fn empty() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.size == 0
    }

    /// Batch plus one request of KV length `kv_len`.
    #[inline]
    pub fn with(self, kv_len: usize) -> Self {
        BatchStats {
            size: self.size + 1,
            total_kv_tokens: self.total_kv_tokens + kv_len,
        }
    }

    /// Batch minus one request of KV length `kv_len`.
    #[inline]
    pub fn without(self, kv_len: usize) -> Self {
        debug_assert!(self.size >= 1 && self.total_kv_tokens >= kv_len);
        BatchStats {
            size: self.size - 1,
            total_kv_tokens: self.total_kv_tokens - kv_len,
        }
    }

    /// Batch plus `count` requests totalling `tokens` KV tokens.
    #[inline]
    pub fn with_group(self, count: usize, tokens: usize) -> Self {
        BatchStats {
            size: self.size + count,
            total_kv_tokens: self.total_kv_tokens + tokens,
        }
    }

    pub fn mean_kv_len(&self) -> f64 {
        if self.size == 0 {
            0.0
        } else {
            self.total_kv_tokens as f64 / self.size as f64
        }
    }
}

/// Prefix sums over a length-sorted candidate list: `stats_of_prefix(k)`
/// answers "what would the batch look like with the first k candidates
/// added" in O(1), which turns Algorithm 2's subset search into a plain
/// binary search.
#[derive(Debug, Clone)]
pub struct PrefixSums {
    sums: Vec<usize>,
}

impl PrefixSums {
    pub fn of(lengths: &[usize]) -> Self {
        let mut sums = Vec::with_capacity(lengths.len() + 1);
        sums.push(0);
        let mut acc = 0usize;
        for &l in lengths {
            acc += l;
            sums.push(acc);
        }
        PrefixSums { sums }
    }

    pub fn len(&self) -> usize {
        self.sums.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total tokens in the first `k` candidates.
    #[inline]
    pub fn prefix_tokens(&self, k: usize) -> usize {
        self.sums[k]
    }

    /// `base` extended with the first `k` candidates.
    #[inline]
    pub fn extend(&self, base: BatchStats, k: usize) -> BatchStats {
        base.with_group(k, self.sums[k])
    }

    /// Largest `k` such that `pred(extend(base, k))` holds, assuming `pred`
    /// is monotone (true for small prefixes, false beyond some point).
    pub fn max_prefix<F: Fn(BatchStats) -> bool>(
        &self,
        base: BatchStats,
        pred: F,
    ) -> usize {
        let (mut lo, mut hi) = (0usize, self.len());
        // Invariant: pred holds at lo; fails beyond hi (or hi untested-ok).
        if !pred(self.extend(base, 0)) {
            return 0;
        }
        while lo < hi {
            let mid = lo + (hi - lo + 1) / 2;
            if pred(self.extend(base, mid)) {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn with_without_inverse() {
        let b = BatchStats::new(5, 900);
        assert_eq!(b.with(100).without(100), b);
        assert_eq!(b.with(0).size, 6);
        assert_eq!(b.with_group(3, 250), BatchStats::new(8, 1150));
    }

    #[test]
    fn mean_kv() {
        assert_eq!(BatchStats::empty().mean_kv_len(), 0.0);
        assert_eq!(BatchStats::new(4, 100).mean_kv_len(), 25.0);
    }

    #[test]
    fn prefix_sums_basic() {
        let p = PrefixSums::of(&[10, 20, 30]);
        assert_eq!(p.len(), 3);
        assert_eq!(p.prefix_tokens(0), 0);
        assert_eq!(p.prefix_tokens(2), 30);
        assert_eq!(p.prefix_tokens(3), 60);
        let b = p.extend(BatchStats::new(1, 5), 3);
        assert_eq!(b, BatchStats::new(4, 65));
    }

    #[test]
    fn max_prefix_monotone_search() {
        let p = PrefixSums::of(&[10, 10, 10, 10, 10]);
        let base = BatchStats::empty();
        // Allow at most 35 total tokens -> k = 3.
        let k = p.max_prefix(base, |b| b.total_kv_tokens <= 35);
        assert_eq!(k, 3);
        // Everything fits.
        assert_eq!(p.max_prefix(base, |b| b.total_kv_tokens <= 1000), 5);
        // Nothing fits.
        assert_eq!(p.max_prefix(base, |b| b.total_kv_tokens <= 5 && b.size == 0), 0);
    }

    #[test]
    fn max_prefix_empty_list() {
        let p = PrefixSums::of(&[]);
        assert_eq!(p.max_prefix(BatchStats::empty(), |_| true), 0);
    }

    #[test]
    fn max_prefix_matches_linear_scan() {
        // Property: binary search result equals the obvious linear scan.
        let lengths: Vec<usize> = (1..=40).map(|i| (i * 13) % 37 + 1).collect();
        let mut sorted = lengths.clone();
        sorted.sort_unstable();
        let p = PrefixSums::of(&sorted);
        for cap in [0usize, 5, 50, 200, 400, 10_000] {
            let base = BatchStats::new(2, 3);
            let pred =
                |b: BatchStats| b.total_kv_tokens.saturating_sub(3) <= cap;
            let want = (0..=sorted.len())
                .take_while(|&k| pred(p.extend(base, k)))
                .last()
                .unwrap_or(0);
            assert_eq!(p.max_prefix(base, pred), want, "cap {cap}");
        }
    }
}
