//! Artifacts manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. The rust side never hard-codes model dimensions — they all
//! come from here.

use std::path::Path;

use anyhow::{bail, Result};

use crate::util::json::Json;

/// One weight leaf's layout inside `weights.bin`.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_bytes: usize,
    pub num_elements: usize,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub seed: u64,
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub q_heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub smax: usize,
    pub prefill_buckets: Vec<usize>,
    pub decode_buckets: Vec<usize>,
    pub weights_file: String,
    pub weights: Vec<WeightSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let v = Json::parse_file(&path)?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        if v.get("format").as_str() != Some("hlo-text") {
            bail!("unsupported manifest format {:?}", v.get("format"));
        }
        let model = v.get("model");
        let buckets = |key: &str| -> Result<Vec<usize>> {
            v.get(key)
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("missing `{key}`"))?
                .iter()
                .map(|x| {
                    x.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("non-integer bucket"))
                })
                .collect()
        };
        let mut weights = Vec::new();
        for w in v
            .get("weights")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("missing `weights`"))?
        {
            weights.push(WeightSpec {
                name: w.req_str("name")?.to_string(),
                shape: w
                    .get("shape")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|d| d.as_usize())
                    .collect(),
                offset_bytes: w.req_usize("offset_bytes")?,
                num_elements: w.req_usize("num_elements")?,
            });
        }
        let m = Manifest {
            seed: v.get("seed").as_u64().unwrap_or(0),
            vocab: model.req_usize("vocab")?,
            hidden: model.req_usize("hidden")?,
            layers: model.req_usize("layers")?,
            q_heads: model.req_usize("q_heads")?,
            kv_heads: model.req_usize("kv_heads")?,
            head_dim: model.req_usize("head_dim")?,
            ffn: model.req_usize("ffn")?,
            smax: model.req_usize("smax")?,
            prefill_buckets: buckets("prefill_buckets")?,
            decode_buckets: buckets("decode_buckets")?,
            weights_file: v.req_str("weights_file")?.to_string(),
            weights,
        };
        if m.prefill_buckets.is_empty() || m.decode_buckets.is_empty() {
            bail!("manifest has empty bucket lists");
        }
        if m.hidden != m.q_heads * m.head_dim {
            bail!("inconsistent manifest: hidden != q_heads * head_dim");
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        Json::parse(
            r#"{
                "format": "hlo-text",
                "seed": 0,
                "model": {"vocab": 512, "hidden": 256, "layers": 4,
                          "q_heads": 8, "kv_heads": 2, "head_dim": 32,
                          "ffn": 512, "smax": 448, "rope_theta": 10000.0,
                          "bytes_per_value": 4},
                "prefill_buckets": [64, 128],
                "decode_buckets": [1, 2, 4],
                "weights_file": "weights.bin",
                "weights": [
                    {"name": "a", "shape": [2, 3], "offset_bytes": 0, "num_elements": 6},
                    {"name": "b", "shape": [4], "offset_bytes": 24, "num_elements": 4}
                ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(&sample()).unwrap();
        assert_eq!(m.vocab, 512);
        assert_eq!(m.smax, 448);
        assert_eq!(m.prefill_buckets, vec![64, 128]);
        assert_eq!(m.weights.len(), 2);
        assert_eq!(m.weights[1].offset_bytes, 24);
    }

    #[test]
    fn rejects_bad_format() {
        let mut v = sample();
        if let Json::Obj(o) = &mut v {
            o.insert("format".into(), Json::Str("protobuf".into()));
        }
        assert!(Manifest::from_json(&v).is_err());
    }

    #[test]
    fn rejects_inconsistent_dims() {
        let v = Json::parse(
            r#"{
                "format": "hlo-text",
                "model": {"vocab": 10, "hidden": 100, "layers": 1,
                          "q_heads": 2, "kv_heads": 1, "head_dim": 32,
                          "ffn": 10, "smax": 64},
                "prefill_buckets": [8], "decode_buckets": [1],
                "weights_file": "w.bin", "weights": []
            }"#,
        )
        .unwrap();
        assert!(Manifest::from_json(&v).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.hidden, m.q_heads * m.head_dim);
        assert!(!m.weights.is_empty());
        assert_eq!(m.weights_file, "weights.bin");
    }
}
