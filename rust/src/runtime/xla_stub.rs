//! API-compatible stand-in for the `xla` (PJRT) crate, used when the crate
//! is built without the `pjrt` feature.
//!
//! The real backend needs the XLA C++ toolchain, which most build hosts
//! (and CI) do not carry; gating it keeps the tier-1 `cargo build && cargo
//! test` green everywhere. The stub mirrors exactly the surface
//! `runtime::Runtime` uses and fails fast at [`PjRtClient::cpu`], so any
//! attempt to actually load artifacts reports a clear error instead of
//! linking garbage. All engine paths (tests, examples, `ooco serve`)
//! already skip when artifacts are absent, which is necessarily the case
//! in a stub build.

/// Error type mirroring `xla::Error` (only `Debug` is needed upstream).
#[derive(Debug)]
pub struct Error(pub String);

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "XLA/PJRT backend not compiled in — vendor the `xla` crate (see the \
         commented dependency in rust/Cargo.toml) and rebuild with \
         `--features pjrt`"
            .to_string(),
    ))
}

/// Stub PJRT client; construction always fails.
#[derive(Debug)]
pub struct PjRtClient;

/// Stub device buffer.
#[derive(Debug)]
pub struct PjRtBuffer;

/// Stub compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

/// Stub host literal.
#[derive(Debug)]
pub struct Literal;

/// Stub HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto;

/// Stub XLA computation.
#[derive(Debug)]
pub struct XlaComputation;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        unavailable()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        unavailable()
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_clear_error() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err:?}").contains("pjrt"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
