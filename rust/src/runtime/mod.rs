//! PJRT runtime: loads the AOT artifacts produced by `python/compile` and
//! executes them from the rust hot path. Python never runs here.
//!
//! Pipeline (see /opt/xla-example and DESIGN.md §4):
//!   `artifacts/manifest.json` -> HLO text -> `HloModuleProto::from_text_file`
//!   -> `XlaComputation` -> `PjRtClient::compile` -> `execute_b`.
//!
//! Weights travel as trailing HLO parameters; [`Runtime::load`] uploads them
//! once as device-resident `PjRtBuffer`s (`weights.bin` -> buffers) so each
//! step only copies its activations. KV caches round-trip as host `Vec<f32>`
//! per request — the rust coordinator owns residency (paging, migration),
//! matching the paper's architecture where KV movement is a scheduling
//! concern.

pub mod manifest;
#[cfg(not(feature = "pjrt"))]
mod xla_stub;

pub use manifest::Manifest;

// Real PJRT bindings with `--features pjrt`; otherwise an API-compatible
// stub that fails fast at client construction, so the coordinator builds
// and tests on hosts without the XLA C++ toolchain (engine tests and
// examples skip when artifacts are absent, which a stub build guarantees).
// A `pjrt` build resolves the `xla::` paths below against a crate
// dependency named `xla`, which must first be vendored and uncommented in
// Cargo.toml — until then, `--features pjrt` fails on these paths by
// design rather than linking a half-present backend.
#[cfg(not(feature = "pjrt"))]
use xla_stub as xla;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Per-request KV cache block: `[L, Hkv, Smax, Dh]` each for K and V.
#[derive(Debug, Clone)]
pub struct KvBuf {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl KvBuf {
    pub fn zeros(len: usize) -> Self {
        KvBuf {
            k: vec![0.0; len],
            v: vec![0.0; len],
        }
    }
}

/// Prefill result: next-token logits + the request's KV cache block.
#[derive(Debug)]
pub struct PrefillOut {
    pub logits: Vec<f32>,
    pub kv: KvBuf,
}

/// One decode-batch entry: the request's last token, its position
/// (== current KV length - 1), and its KV block (updated in place).
pub struct DecodeEntry<'a> {
    pub token: i32,
    pub position: i32,
    pub kv: &'a mut KvBuf,
}

/// Loaded PJRT runtime with all shape buckets compiled.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    prefill_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    decode_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    weights: Vec<xla::PjRtBuffer>,
}

impl Runtime {
    /// Load manifest, weights and compile every bucket executable.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt client: {e:?}"))?;

        // Upload weights once as device-resident buffers.
        let blob = std::fs::read(dir.join(&manifest.weights_file))
            .with_context(|| "reading weights.bin")?;
        let mut weights = Vec::with_capacity(manifest.weights.len());
        for spec in &manifest.weights {
            let start = spec.offset_bytes;
            let end = start + spec.num_elements * 4;
            if end > blob.len() {
                bail!("weights.bin too short for {}", spec.name);
            }
            let floats: Vec<f32> = blob[start..end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let dims: Vec<usize> = if spec.shape.is_empty() {
                vec![1]
            } else {
                spec.shape.clone()
            };
            let buf = client
                .buffer_from_host_buffer(&floats, &dims, None)
                .map_err(|e| anyhow::anyhow!("weight upload {}: {e:?}", spec.name))?;
            weights.push(buf);
        }

        let mut prefill_exes = BTreeMap::new();
        for &s in &manifest.prefill_buckets {
            let path = dir.join(format!("prefill_s{s}.hlo.txt"));
            prefill_exes.insert(s, compile(&client, &path)?);
        }
        let mut decode_exes = BTreeMap::new();
        for &b in &manifest.decode_buckets {
            let path = dir.join(format!("decode_b{b}.hlo.txt"));
            decode_exes.insert(b, compile(&client, &path)?);
        }

        Ok(Runtime {
            manifest,
            client,
            prefill_exes,
            decode_exes,
            weights,
        })
    }

    /// Elements in one request's K (or V) cache block.
    pub fn kv_elems(&self) -> usize {
        let m = &self.manifest;
        m.layers * m.kv_heads * m.smax * m.head_dim
    }

    /// Smallest prefill bucket >= `len` (error if prompt too long).
    pub fn prefill_bucket(&self, len: usize) -> Result<usize> {
        self.manifest
            .prefill_buckets
            .iter()
            .copied()
            .find(|&s| s >= len)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "prompt of {len} tokens exceeds largest bucket {:?}",
                    self.manifest.prefill_buckets.last()
                )
            })
    }

    /// Smallest decode bucket >= `batch`.
    pub fn decode_bucket(&self, batch: usize) -> Result<usize> {
        self.manifest
            .decode_buckets
            .iter()
            .copied()
            .find(|&b| b >= batch)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "decode batch {batch} exceeds largest bucket {:?}",
                    self.manifest.decode_buckets.last()
                )
            })
    }

    /// Largest decode bucket (the engine's max batch size).
    pub fn max_decode_batch(&self) -> usize {
        *self.manifest.decode_buckets.last().unwrap_or(&1)
    }

    /// Run a prefill for one request. `tokens.len()` must be <= the largest
    /// bucket and <= `smax - 1` (room to decode at least one token).
    pub fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
        let len = tokens.len();
        if len == 0 {
            bail!("empty prompt");
        }
        if len >= self.manifest.smax {
            bail!("prompt {len} >= smax {}", self.manifest.smax);
        }
        let bucket = self.prefill_bucket(len)?;
        let exe = &self.prefill_exes[&bucket];

        let mut padded = tokens.to_vec();
        padded.resize(bucket, 0);
        let tok_buf = self
            .client
            .buffer_from_host_buffer(&padded, &[bucket], None)
            .map_err(xe)?;
        let len_buf = self
            .client
            .buffer_from_host_buffer(&[len as i32], &[], None)
            .map_err(xe)?;
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(2 + self.weights.len());
        args.push(&tok_buf);
        args.push(&len_buf);
        for w in &self.weights {
            args.push(w);
        }
        let out = exe.execute_b::<&xla::PjRtBuffer>(&args).map_err(xe)?;
        let lit = out[0][0].to_literal_sync().map_err(xe)?;
        let parts = lit.to_tuple().map_err(xe)?;
        let logits = parts[0].to_vec::<f32>().map_err(xe)?;
        let k = parts[1].to_vec::<f32>().map_err(xe)?;
        let v = parts[2].to_vec::<f32>().map_err(xe)?;
        Ok(PrefillOut {
            logits,
            kv: KvBuf { k, v },
        })
    }

    /// Run one decode step over a batch. Each entry's KV block is updated
    /// in place; returns per-entry logits.
    pub fn decode(&self, entries: &mut [DecodeEntry<'_>]) -> Result<Vec<Vec<f32>>> {
        if entries.is_empty() {
            return Ok(vec![]);
        }
        let n = entries.len();
        let bucket = self.decode_bucket(n)?;
        let exe = &self.decode_exes[&bucket];
        let kv_elems = self.kv_elems();

        // Assemble padded batch tensors (per-request-contiguous KV layout).
        let mut tokens = vec![0i32; bucket];
        let mut positions = vec![0i32; bucket];
        let mut k = vec![0f32; bucket * kv_elems];
        let mut v = vec![0f32; bucket * kv_elems];
        for (i, e) in entries.iter().enumerate() {
            tokens[i] = e.token;
            positions[i] = e.position;
            k[i * kv_elems..(i + 1) * kv_elems].copy_from_slice(&e.kv.k);
            v[i * kv_elems..(i + 1) * kv_elems].copy_from_slice(&e.kv.v);
        }
        let m = &self.manifest;
        let kv_dims = [bucket, m.layers, m.kv_heads, m.smax, m.head_dim];

        let tok_buf = self
            .client
            .buffer_from_host_buffer(&tokens, &[bucket], None)
            .map_err(xe)?;
        let pos_buf = self
            .client
            .buffer_from_host_buffer(&positions, &[bucket], None)
            .map_err(xe)?;
        let k_buf = self
            .client
            .buffer_from_host_buffer(&k, &kv_dims, None)
            .map_err(xe)?;
        let v_buf = self
            .client
            .buffer_from_host_buffer(&v, &kv_dims, None)
            .map_err(xe)?;
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(4 + self.weights.len());
        args.push(&tok_buf);
        args.push(&pos_buf);
        args.push(&k_buf);
        args.push(&v_buf);
        for w in &self.weights {
            args.push(w);
        }
        let out = exe.execute_b::<&xla::PjRtBuffer>(&args).map_err(xe)?;
        let lit = out[0][0].to_literal_sync().map_err(xe)?;
        let parts = lit.to_tuple().map_err(xe)?;
        let logits_flat = parts[0].to_vec::<f32>().map_err(xe)?;
        let k_out = parts[1].to_vec::<f32>().map_err(xe)?;
        let v_out = parts[2].to_vec::<f32>().map_err(xe)?;

        let vocab = self.manifest.vocab;
        let mut result = Vec::with_capacity(n);
        for (i, e) in entries.iter_mut().enumerate() {
            result.push(logits_flat[i * vocab..(i + 1) * vocab].to_vec());
            e.kv.k.copy_from_slice(&k_out[i * kv_elems..(i + 1) * kv_elems]);
            e.kv.v.copy_from_slice(&v_out[i * kv_elems..(i + 1) * kv_elems]);
        }
        Ok(result)
    }
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 path")?,
    )
    .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))
}

fn xe(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e:?}")
}
