//! OOCO command-line launcher.
//!
//! Subcommands:
//!   serve      — real PJRT engine over the AOT artifacts (tiny model)
//!   simulate   — discrete-event cluster simulation at 7B/72B scale
//!   sweep      — SLO-attainment-vs-load curve (machine-readable JSON)
//!   bench      — standardized perf suite with self-profiling (§3.11)
//!   roofline   — query the performance model
//!   trace      — generate and export a workload trace (JSON)
//!   analyze    — offline incident ledger + Markdown postmortem from a
//!                recorded `--json-out` report (§3.12)

use std::time::Instant;

use ooco::config::{FaultSpec, FleetSpec, ModelSpec, ServingConfig};
use ooco::coordinator::Policy;
use ooco::fleet::{simulate_fleet_observed, FleetConfig};
use ooco::obs;
use ooco::sim::{simulate_observed, SimConfig};
use ooco::telemetry::TelemetryOpts;
use ooco::trace::datasets::DatasetProfile;
use ooco::trace::generator::{offline_trace, online_trace};
use ooco::trace::io::save_trace;
use ooco::trace::scale_trace;
use ooco::util::cli::Args;
use ooco::util::json::Json;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let all: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match all.split_first() {
        Some((c, rest)) if !c.starts_with("--") => (c.as_str(), rest.to_vec()),
        _ => {
            print_usage();
            return Ok(());
        }
    };
    let args = Args::parse(rest);
    ooco::util::logging::set_level_from_str(args.str("log", "info"));

    match cmd {
        "serve" => cmd_serve(&args),
        "simulate" => cmd_simulate(&args),
        "sweep" => cmd_sweep(&args),
        "bench" => cmd_bench(&args),
        "roofline" => cmd_roofline(&args),
        "trace" => cmd_trace(&args),
        "analyze" => cmd_analyze(&args),
        other => {
            print_usage();
            anyhow::bail!("unknown subcommand `{other}`")
        }
    }
}

fn print_usage() {
    eprintln!(
        "ooco — latency-disaggregated online-offline co-located LLM serving

USAGE: ooco <serve|simulate|sweep|bench|roofline|trace|analyze> [--flags]

  serve     --duration 20 --online-rate 1 --offline-qps 1 --policy ooco
            [--artifacts artifacts] [--seed 42]
  simulate  --model 7b --dataset azure-conv --online-rate 0.5
            --offline-qps 10 --duration 1800 --policy ooco
            [--trace trace.json]  (replay a saved trace instead)
            [--relaxed 1 --strict 1]
            [--pool-policy static|periodic|reactive|'periodic(epoch=60,headroom=0.15)']
            [--prefix-profile none|shared-system|few-shot|agentic]
            [--prefix-cache true|false]
            [--chunk-tokens auto|off|<n>]
            [--prompt-profile dataset|'long-prompt(mean=6000,sigma=1.2,max=16384)']
            [--ablation full] [--overload best-effort|shed] [--seed 42]
            [--fleet 2|'fleet(replicas=2,route=least,steal=4)']
            [--fault 'crash(at=600,replica=0,pool=relaxed,inst=1,down=120,notice=30); mtbf(mean=900,mttr=60)']
            [--json-out result.json]  (adds timeline + attribution keys)
            [--metrics-out metrics.prom]  (OpenMetrics text exposition)
            [--profile]  (self-profiler breakdown in the JSON `profile` key)
            [--trace-out trace.perfetto.json]  (Chrome/Perfetto timeline)
            [--progress]  (events/s + ETA heartbeat on stderr)
            [--watch true|false]  (streaming incident engine, §3.12;
             on by default with any telemetry output — `incidents` key,
             Perfetto annotation track, OpenMetrics families; `false`
             restores byte-identical watchdog-less output)
            [--slo-gate 0.97]  (exit code 3 when final online SLO
             attainment falls below the threshold)
  sweep     --policy ooco --online-rate 0.5 --qps 1,2,4,8 --duration 600
            [--pool-policy static] [--relaxed 1 --strict 1]
            [--prefix-profile shared-system|few-shot|agentic]
            [--prefix-cache true|false]
            [--jobs N]  (parallel load levels; output identical to --jobs 1)
            [--json-out curve.json]
  bench     [--scale 1.0] [--seed 42] [--json-out BENCH_sim.json]
            (standardized 4-scenario perf suite, self-profiled; emits the
             schema-stable trajectory artifact CI gates against)
  roofline  --model 7b --hw 910c --batch 128 --kv-len 1000 --prompt 1892
  trace     --dataset azure-conv --rate 1.0 --duration 3600 --scale 1.0
            --out trace.json [--offline-qps 0]
            [--prefix-profile 'shared-system(len=1024)'|'few-shot(groups=8,len=1024)'|'agentic(convs=16,turns=6)']
            (shared-prefix families apply to the offline portion)
            [--prompt-profile dataset|long-prompt|'long-prompt(mean=6000,sigma=1.2,max=16384)']
            (prompt-length override applies to both portions)
  analyze   --report result.json [--md-out postmortem.md]
            [--json-out incidents.json]
            (offline incident ledger + Markdown postmortem from any
             recorded `--json-out` report; reuses the streaming ledger
             verbatim when present, re-derives from gauges otherwise)"
    );
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use ooco::engine::{serve_trace_with_runtime, EngineConfig};
    use ooco::runtime::Runtime;
    use ooco::trace::datasets::LengthProfile;

    let dir = std::path::PathBuf::from(args.str("artifacts", "artifacts"));
    let rt = Runtime::load(&dir)?;
    let duration = args.f64("duration", 20.0);
    let seed = args.u64("seed", 42);

    let max_prompt = rt.manifest.smax / 2;
    let mut online_ds = DatasetProfile::azure_conv();
    online_ds.prompt = LengthProfile::new(96.0, 0.6, 8, max_prompt);
    online_ds.output = LengthProfile::new(10.0, 0.5, 1, 16);
    let mut offline_ds = DatasetProfile::ooc_offline();
    offline_ds.prompt = LengthProfile::new(128.0, 0.6, 8, max_prompt);
    offline_ds.output = LengthProfile::new(12.0, 0.5, 1, 16);
    let trace = online_trace(online_ds, args.f64("online-rate", 1.0), duration, seed)
        .merge(offline_trace(
            offline_ds,
            args.f64("offline-qps", 1.0),
            duration,
            seed + 1,
        ));

    let cfg = EngineConfig {
        policy: args.parse_flag("policy", Policy::Ooco)?,
        max_output: args.usize("max-output", 16),
        seed,
        ..Default::default()
    };
    let out = serve_trace_with_runtime(&rt, &trace, &cfg)?;
    println!("{}", out.report.summary_line());
    println!(
        "prefills {} strict_steps {} relaxed_steps {} wall {:.1}s",
        out.prefills, out.strict_steps, out.relaxed_steps, out.wall_s
    );
    if let Some(path) = args.opt_str("metrics-out") {
        let mut j = Json::obj(vec![
            ("report", out.report.to_json()),
            ("prefills", Json::Num(out.prefills as f64)),
            ("strict_steps", Json::Num(out.strict_steps as f64)),
            ("relaxed_steps", Json::Num(out.relaxed_steps as f64)),
            ("wall_s", Json::Num(out.wall_s)),
        ]);
        j.set("meta", obs::meta_json(seed, &format!("{cfg:?}"), out.wall_s));
        std::fs::write(path, ooco::obs::openmetrics::render(&j))?;
        println!("wrote OpenMetrics exposition to {path}");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    use ooco::trace::generator::offline_trace_with_prefix;
    use ooco::trace::{PrefixProfile, PromptProfile};

    let seed = args.u64("seed", 42);
    let duration = args.f64("duration", 1800.0);
    let trace = match args.opt_str("trace") {
        Some(path) => {
            ooco::trace::io::load_trace(std::path::Path::new(path))?
        }
        None => {
            let prompt: PromptProfile =
                args.parse_flag("prompt-profile", PromptProfile::Dataset)?;
            let online_ds = prompt.apply(&DatasetProfile::by_name(
                args.str("dataset", "azure-conv"),
            )?);
            let offline_ds = prompt.apply(&DatasetProfile::ooc_offline());
            let prefix: PrefixProfile =
                args.parse_flag("prefix-profile", PrefixProfile::None)?;
            online_trace(online_ds, args.f64("online-rate", 0.5), duration, seed)
                .merge(offline_trace_with_prefix(
                    offline_ds,
                    args.f64("offline-qps", 10.0),
                    duration,
                    prefix,
                    seed + 1,
                ))
        }
    };
    let serving = serving_from_args(args)?;
    let mut cfg =
        SimConfig::new(serving, args.parse_flag("policy", Policy::Ooco)?);
    cfg.overload_mode =
        args.parse_flag("overload", ooco::coordinator::OverloadMode::BestEffort)?;
    cfg.ablation = args.parse_flag("ablation", ooco::coordinator::Ablation::full())?;
    cfg.seed = seed;

    // Flight recorder: enabled whenever an output that needs it was
    // requested; library/bench callers keep the zero-overhead no-op.
    let trace_out = args.opt_str("trace-out");
    let json_out = args.opt_str("json-out");
    let metrics_out = args.opt_str("metrics-out");
    let profile = args.bool("profile", false);
    let progress = args.bool("progress", false);
    let telemetry_opts = if trace_out.is_some()
        || progress
        || json_out.is_some()
        || metrics_out.is_some()
    {
        let mut opts = TelemetryOpts::new(cfg.serving.slo);
        opts.perfetto = trace_out.is_some();
        opts.progress = progress;
        // Incident engine (§3.12): on by default alongside telemetry;
        // `--watch false` restores the watchdog-less byte stream.
        if args.bool("watch", true) {
            opts.watch = Some(ooco::watch::WatchParams::new(cfg.serving.slo));
        }
        Some(opts)
    } else {
        None
    };
    let write_trace = |tel: &Option<ooco::telemetry::TelemetryOut>|
     -> anyhow::Result<()> {
        if let (Some(path), Some(tel)) = (trace_out, tel.as_ref()) {
            if let Some(perfetto) = &tel.perfetto {
                std::fs::write(path, perfetto)?;
                println!("wrote Perfetto trace to {path}");
            }
        }
        Ok(())
    };

    // Fleet mode: any multi-replica topology or fault schedule routes
    // through the fleet layer (DESIGN.md §3.9). A single-replica
    // zero-fault fleet is bit-identical to the plain path below.
    let fleet_spec: FleetSpec = args.parse_flag("fleet", FleetSpec::default())?;
    let fault: FaultSpec = args.parse_flag("fault", FaultSpec::none())?;
    if fleet_spec.replicas > 1 || !fault.is_none() {
        let fcfg = FleetConfig {
            sim: cfg.clone(),
            fleet: fleet_spec,
            fault,
        };
        let started = Instant::now();
        let res =
            simulate_fleet_observed(&trace, &fcfg, telemetry_opts, profile);
        let wall_s = started.elapsed().as_secs_f64();
        println!("{}", res.report.summary_line());
        println!("{}", res.fleet.summary_line());
        if let Some(p) = &res.profile {
            println!("{}", p.summary_line());
        }
        if json_out.is_some() || metrics_out.is_some() {
            let mut out = ooco::fleet::result_json(&fcfg, &res);
            out.set(
                "meta",
                obs::meta_json(seed, &format!("{fcfg:?}"), wall_s),
            );
            write_result(&out, json_out, metrics_out)?;
        }
        write_trace(&res.telemetry)?;
        apply_slo_gate(args, &res.report)?;
        return Ok(());
    }

    let started = Instant::now();
    let res = simulate_observed(&trace, &cfg, telemetry_opts, profile);
    let wall_s = started.elapsed().as_secs_f64();
    println!("{}", res.report.summary_line());
    println!(
        "strict util {:.1}% relaxed util {:.1}% migrations {} evictions {} preemptions {} rescues {}",
        res.strict_utilization * 100.0,
        res.relaxed_utilization * 100.0,
        res.migrations,
        res.evictions,
        res.preemptions,
        res.rescues
    );
    println!("{}", res.transport.summary_line());
    if cfg.serving.pool.is_elastic() {
        println!("{}", res.pool.summary_line());
    }
    if cfg.serving.prefix.enabled && res.prefix.lookups > 0 {
        println!("{}", res.prefix.summary_line());
    }
    if res.chunk.enabled {
        println!("{}", res.chunk.summary_line());
    }
    if let Some(p) = &res.profile {
        println!("{}", p.summary_line());
    }
    if json_out.is_some() || metrics_out.is_some() {
        let mut out = ooco::sim::result_json(&cfg, &res);
        out.set("meta", obs::meta_json(seed, &format!("{cfg:?}"), wall_s));
        write_result(&out, json_out, metrics_out)?;
    }
    write_trace(&res.telemetry)?;
    apply_slo_gate(args, &res.report)?;
    Ok(())
}

/// `--slo-gate <attainment>`: exit with code 3 when the final online SLO
/// attainment falls below the threshold. Runs after every artifact has
/// been written so a failing gate still leaves the evidence on disk for
/// `ooco analyze`.
fn apply_slo_gate(
    args: &Args,
    report: &ooco::metrics::Report,
) -> anyhow::Result<()> {
    let Some(raw) = args.opt_str("slo-gate") else {
        return Ok(());
    };
    let gate: f64 = raw.parse().map_err(|_| {
        anyhow::anyhow!("--slo-gate expects an attainment fraction, got `{raw}`")
    })?;
    let att = report.slo_attainment();
    if att < gate {
        eprintln!("slo-gate: online SLO attainment {att:.4} < gate {gate:.4}");
        std::process::exit(3);
    }
    println!("slo-gate: online SLO attainment {att:.4} >= {gate:.4}");
    Ok(())
}

/// Write the composed `--json-out` object and/or its OpenMetrics
/// rendering (`--metrics-out`).
fn write_result(
    out: &Json,
    json_out: Option<&str>,
    metrics_out: Option<&str>,
) -> anyhow::Result<()> {
    if let Some(path) = json_out {
        std::fs::write(path, out.to_pretty())?;
        println!("wrote machine-readable result to {path}");
    }
    if let Some(path) = metrics_out {
        std::fs::write(path, ooco::obs::openmetrics::render(out))?;
        println!("wrote OpenMetrics exposition to {path}");
    }
    Ok(())
}

/// Standardized self-profiled perf suite (DESIGN.md §3.11): four
/// scenarios, one schema-stable artifact. CI runs this on every PR and
/// gates the headline against the committed `BENCH_baseline.json`.
fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    let scale = args.f64("scale", 1.0);
    let seed = args.u64("seed", 42);
    let (json, summaries) = ooco::obs::bench::run_suite(scale, seed);
    for line in &summaries {
        println!("{line}");
    }
    if let Json::Num(headline) = json.get("headline_req_per_s") {
        println!("bench headline: {headline:.0} req/s");
    }
    let path = args.str("json-out", "BENCH_sim.json");
    std::fs::write(path, json.to_pretty())?;
    println!("wrote bench artifact to {path}");
    Ok(())
}

/// Shared `simulate`/`sweep` serving-config assembly: config file first
/// (e.g. configs/serve_7b_910c.json), then flag overrides.
fn serving_from_args(args: &Args) -> anyhow::Result<ServingConfig> {
    let mut serving = match args.opt_str("config") {
        Some(path) => ServingConfig::from_file(std::path::Path::new(path))?,
        None => ServingConfig::preset_7b(),
    };
    if let Some(m) = args.opt_str("model") {
        serving.model = m.parse::<ModelSpec>()?;
    }
    serving.cluster.relaxed_instances =
        args.usize("relaxed", serving.cluster.relaxed_instances);
    serving.cluster.strict_instances =
        args.usize("strict", serving.cluster.strict_instances);
    serving.pool = args.parse_flag("pool-policy", serving.pool)?;
    serving.prefix.enabled =
        args.bool("prefix-cache", serving.prefix.enabled);
    serving.chunk_tokens =
        args.parse_flag("chunk-tokens", serving.chunk_tokens)?;
    Ok(serving)
}

/// SLO-attainment-vs-load curve: sweep offline QPS at a fixed online rate
/// and emit the machine-readable curve for cross-run comparisons.
fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    use ooco::sweep::{curve_to_json, offline_sweep_parallel, SweepConfig};

    let serving = serving_from_args(args)?;
    let policy = args.parse_flag("policy", Policy::Ooco)?;
    let prompt: ooco::trace::PromptProfile =
        args.parse_flag("prompt-profile", ooco::trace::PromptProfile::Dataset)?;
    let online_ds = prompt.apply(&DatasetProfile::by_name(
        args.str("dataset", "azure-conv"),
    )?);
    let qps = args.f64_list("qps", &[1.0, 2.0, 4.0, 8.0]);
    let sweep_cfg = SweepConfig {
        duration_s: args.f64("duration", 600.0),
        seed: args.u64("seed", 42),
        ablation: args.parse_flag("ablation", ooco::coordinator::Ablation::full())?,
        offline_prefix: args.parse_flag(
            "prefix-profile",
            ooco::trace::PrefixProfile::None,
        )?,
    };
    let jobs = args.usize("jobs", 1).max(1);
    let started = Instant::now();
    let points = offline_sweep_parallel(
        &serving,
        policy,
        &online_ds,
        args.f64("online-rate", 0.5),
        &prompt.apply(&DatasetProfile::ooc_offline()),
        &qps,
        &sweep_cfg,
        jobs,
    );
    let wall_s = started.elapsed().as_secs_f64();
    for p in &points {
        println!(
            "qps {:6.2} | attainment {:6.2}% | offline {:8.1} tok/s | ttft p99 {:.3}s tpot p99 {:.1}ms | prefix hit {:.1}%",
            p.offline_qps,
            (1.0 - p.violation_rate) * 100.0,
            p.offline_token_throughput,
            p.ttft_p99,
            p.tpot_p99 * 1e3,
            p.prefix_hit_rate * 100.0,
        );
    }
    let label = format!("{policy}+{}", serving.pool);
    let mut curve = curve_to_json(&label, &points);
    curve.set(
        "meta",
        obs::meta_json(
            sweep_cfg.seed,
            &format!("{label};{serving:?};qps={qps:?};{sweep_cfg:?}"),
            wall_s,
        ),
    );
    if let Some(path) = args.opt_str("json-out") {
        std::fs::write(path, curve.to_pretty())?;
        println!("wrote SLO-attainment-vs-load curve to {path}");
    } else {
        println!("{}", curve.to_string());
    }
    Ok(())
}

fn cmd_roofline(args: &Args) -> anyhow::Result<()> {
    use ooco::perfmodel::{BatchStats, PerfModel};
    let model = args.str("model", "7b").parse::<ModelSpec>()?;
    let hw = args
        .str("hw", "910c")
        .parse::<ooco::config::HardwareProfile>()?;
    let pm = PerfModel::new(model, hw);
    let batch = args.usize("batch", 128);
    let kv = args.usize("kv-len", 1000);
    let prompt = args.usize("prompt", 1892);
    println!(
        "prefill({prompt}) = {:.2} ms | decode({batch}x{kv}) = {:.2} ms | bs_sat {} | kv_cap {}",
        pm.prefill_latency(prompt) * 1e3,
        pm.decode_latency(BatchStats::new(batch, batch * kv)) * 1e3,
        pm.bs_sat(),
        pm.max_kv_tokens()
    );
    Ok(())
}

/// Offline incident analysis (§3.12): fold a recorded `--json-out`
/// report into an incident ledger — verbatim when the run streamed one,
/// re-derived from the gauge timeline otherwise — and render the
/// Markdown postmortem (stdout unless `--md-out`).
fn cmd_analyze(args: &Args) -> anyhow::Result<()> {
    use ooco::watch::analyze::{ledger_from_report, postmortem_md};

    let path = args.opt_str("report").ok_or_else(|| {
        anyhow::anyhow!(
            "--report <result.json> is required (a simulate `--json-out` \
             artifact)"
        )
    })?;
    let report = Json::parse_file(std::path::Path::new(path))?;
    let ledger = ledger_from_report(&report);
    if let Some(out) = args.opt_str("json-out") {
        std::fs::write(out, ledger.to_pretty())?;
        println!("wrote incident ledger to {out}");
    }
    let md = postmortem_md(&report, &ledger);
    match args.opt_str("md-out") {
        Some(out) => {
            std::fs::write(out, &md)?;
            println!("wrote postmortem to {out}");
            if let Json::Num(total) = ledger.get("total") {
                println!("incidents: {total:.0}");
            }
        }
        None => print!("{md}"),
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    use ooco::trace::generator::offline_trace_with_prefix;
    use ooco::trace::{PrefixProfile, PromptProfile};

    let seed = args.u64("seed", 42);
    let duration = args.f64("duration", 3600.0);
    let prompt: PromptProfile =
        args.parse_flag("prompt-profile", PromptProfile::Dataset)?;
    let ds = prompt
        .apply(&DatasetProfile::by_name(args.str("dataset", "azure-conv"))?);
    let mut trace = online_trace(ds, args.f64("rate", 1.0), duration, seed);
    let offline_qps = args.f64("offline-qps", 0.0);
    let prefix: PrefixProfile =
        args.parse_flag("prefix-profile", PrefixProfile::None)?;
    if offline_qps > 0.0 {
        trace = trace.merge(offline_trace_with_prefix(
            prompt.apply(&DatasetProfile::ooc_offline()),
            offline_qps,
            duration,
            prefix,
            seed + 1,
        ));
    } else if prefix != PrefixProfile::None {
        anyhow::bail!(
            "--prefix-profile applies to the offline portion; set \
             --offline-qps > 0"
        );
    }
    let scale = args.f64("scale", 1.0);
    if (scale - 1.0).abs() > 1e-9 {
        trace = scale_trace(&trace, scale, seed + 2);
    }
    let out = std::path::PathBuf::from(args.str("out", "trace.json"));
    save_trace(&trace, &out)?;
    println!(
        "wrote {} requests ({} online / {} offline) to {}",
        trace.len(),
        trace.count_class(ooco::request::Class::Online),
        trace.count_class(ooco::request::Class::Offline),
        out.display()
    );
    Ok(())
}
