//! Flight-recorder telemetry (DESIGN.md §3.10).
//!
//! The scheduler's observable behavior is its typed [`Action`] stream —
//! the same stream the differential tests assert. This module taps that
//! single choke point and reconstructs, *without touching the decision
//! code*, everything an operator needs to see about a run:
//!
//! - **per-request lifecycle spans** — arrival → queue → admit → prefill
//!   chunks → preemption / eviction / migration / transfer → decode →
//!   complete, with the instance, pool, and cause attached;
//! - **per-instance tracks** — every iteration as a slice (kind,
//!   composition, cached tokens), pool flips, preemptions, and crash
//!   windows;
//! - **a periodic gauge sampler** — pool sizes, KV occupancy, queue
//!   depths, link utilization, and sliding-window SLO attainment,
//!   emitted as the `timeline` key of `--json-out`;
//! - **a Chrome/Perfetto trace** (`--trace-out`) with flow arrows linking
//!   evictions, KV transfers, and the rescued request's next step across
//!   instances; and
//! - **an SLO-violation attribution report** decomposing each violated
//!   online request's TTFT and TPOT into queueing, transfer-stall,
//!   chunk-interference, and compute components whose sum reproduces the
//!   measured latency exactly (queueing is the closed-form residual).
//!
//! The default [`TraceRecorder::disabled`] recorder is a single `Option`
//! check per executor callback — the simulator's hot loop pays nothing
//! when tracing is off (guarded by `benches/bench_sim_throughput`).
//!
//! Everything recorded derives from the deterministic action stream and
//! the virtual clock, so for a fixed seed and config the Perfetto JSON
//! and the `timeline`/`attribution` values are byte-identical across
//! runs (asserted by `tests/telemetry_properties.rs` and the fleet
//! determinism test). Wall-clock time is used only for the optional
//! `--progress` stderr lines.

use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

use crate::config::SloSpec;
use crate::instance::{PoolRole, StepKind};
use crate::metrics::RequestRecord;
use crate::obs::{self, Subsystem};
use crate::request::{Class, Request, RequestId};
use crate::scheduler::action::{Action, InstanceRef, RolePhase};
use crate::scheduler::cluster::ClusterState;
use crate::transport::{JobId, LinkState, TransferKind};
use crate::util::json::Json;

/// Sliding window (virtual seconds) of the gauge sampler's SLO-attainment
/// estimate.
const ATTAINMENT_WINDOW_S: f64 = 60.0;
/// Perfetto thread id of the per-replica pool-manager notice track.
const TID_POOL_MANAGER: usize = 50;
/// Perfetto thread id of the incident-engine annotation track
/// (DESIGN.md §3.12), one per replica process.
const TID_WATCHDOG: usize = 60;
/// Perfetto thread ids of instance tracks start here (one per physical
/// GPU, stable across role flips).
const TID_INSTANCE_BASE: usize = 100;
/// Perfetto thread ids of transfer-lane tracks start here; clusters large
/// enough to collide with this base are far beyond simulated scales.
const TID_LANE_BASE: usize = 300;
/// Concurrent-transfer lanes rendered per link before slices stack.
const LANES_PER_LINK: usize = 32;
const EPS: f64 = 1e-9;

// ----------------------------------------------------------------- options

/// Configuration of an enabled flight recorder.
#[derive(Debug, Clone, Copy)]
pub struct TelemetryOpts {
    /// Build the Chrome/Perfetto trace-event buffer (`--trace-out`).
    pub perfetto: bool,
    /// Gauge sampling cadence in virtual seconds.
    pub sample_interval_s: f64,
    /// SLO bounds used for the attainment gauge and the attribution
    /// report's violation classification.
    pub slo: SloSpec,
    /// Emit periodic progress lines on stderr (wall-clock rates; never
    /// part of the deterministic outputs).
    pub progress: bool,
    /// Arm the streaming incident engine (DESIGN.md §3.12) with these
    /// parameters. `None` (the default) leaves every output byte-identical
    /// to a watchdog-less build — the watchdog is a pure observer. The
    /// engine itself is attached via [`TraceRecorder::arm_watch`] because
    /// it needs the serving config (perf model) at construction.
    pub watch: Option<crate::watch::WatchParams>,
}

impl TelemetryOpts {
    pub fn new(slo: SloSpec) -> Self {
        TelemetryOpts {
            perfetto: false,
            sample_interval_s: 5.0,
            slo,
            progress: false,
            watch: None,
        }
    }
}

// ------------------------------------------------------------------ output

/// Everything a finished recorder hands back to the caller.
#[derive(Debug, Clone)]
pub struct TelemetryOut {
    /// Gauge-sampler series — the `timeline` key of `--json-out`.
    pub timeline: Json,
    /// SLO-violation attribution report — the `attribution` key.
    pub attribution: Json,
    /// Chrome trace-event JSON (present when
    /// [`TelemetryOpts::perfetto`] was set).
    pub perfetto: Option<String>,
    /// Incident-engine ledger — the `incidents` key of `--json-out`
    /// (present only when the watchdog was armed, DESIGN.md §3.12).
    pub incidents: Option<Json>,
    /// Span well-formedness counters for the property tests.
    pub audit: SpanAudit,
}

/// Structural invariants of the recorded spans, checked by
/// `tests/telemetry_properties.rs`.
#[derive(Debug, Clone, Copy, Default)]
pub struct SpanAudit {
    /// Step spans opened (one per observed `StartStep`).
    pub opened_spans: u64,
    /// Spans closed by a successor step on the same track, a preemption
    /// path, or a crash.
    pub closed_spans: u64,
    /// Spans still open when the run ended (0 for a drained run).
    pub force_closed_spans: u64,
    /// Track-local timestamp regressions (a step starting measurably
    /// before its predecessor's end).
    pub monotone_violations: u64,
    /// Actions naming an instance outside the registered topology.
    pub dangling_instance_refs: u64,
    /// Completed chunked-prefill requests whose chunk-span accounting
    /// was checked (exclusive-mode prefills carry no chunk segments and
    /// are skipped).
    pub chunk_audited: u64,
    /// Audited requests whose final-attempt chunk spans did not sum to
    /// the measured `prefill_target - prefill_cached`.
    pub chunk_mismatches: u64,
    /// Attribution rows emitted (violated online requests).
    pub attribution_rows: u64,
    /// Worst |component sum − measured TTFT| over all attribution rows.
    pub max_attr_residual: f64,
}

// ---------------------------------------------------------- recorder state

/// Attribution interval of one pre-first-token step: `own` is the share
/// of the iteration's token work belonging to this request (the rest is
/// chunk interference).
#[derive(Debug, Clone, Copy)]
struct StepInterval {
    start: f64,
    end: f64,
    own: f64,
}

/// Where an open step's per-participant attribution went, so preemption
/// and crash truncation can patch it.
#[derive(Debug, Clone, Copy)]
enum PartRef {
    /// Index into the request's pre-first-token interval list.
    Pre(usize),
    /// Decode-phase scalar contribution (union cursor accounting).
    Dec {
        eff_start: f64,
        compute: f64,
        interfere: f64,
    },
    None,
}

/// A step span awaiting its end (closed by the next step on the track,
/// a preemption reschedule, a crash, or end-of-run force close).
#[derive(Debug)]
struct OpenStep {
    ev_idx: Option<usize>,
    start: f64,
    end: f64,
    kind: StepKind,
    parts: Vec<(RequestId, PartRef)>,
}

/// Per-request recorder state: workload statics, milestone estimates,
/// prefill-chunk audit credit, and attribution accumulators.
#[derive(Debug, Clone, Default)]
struct ReqTrack {
    online: bool,
    arrival: f64,
    prompt_len: usize,
    output_len: usize,
    admitted_at: Option<f64>,
    first_token_est: Option<f64>,
    finished_est: Option<f64>,
    evictions: u32,
    /// Current KV home `(replica, pool, index)` — flow-arrow anchor.
    home: Option<(usize, u8, usize)>,
    /// Uncached prefill tokens announced by composed-iteration chunk
    /// segments for the current attempt; reset on eviction (recompute)
    /// and on exclusive-mode preemption (work discarded), audited
    /// against the measured `prefill_target - prefill_cached`.
    prefill_credit: i64,
    /// The current prefill attempt ran (at least partly) as an
    /// exclusive step, which carries no chunk segments — the chunk
    /// audit does not apply to this request.
    exclusive_prefill: bool,
    pre_steps: Vec<StepInterval>,
    pre_transfers: Vec<(f64, f64)>,
    dec_busy_until: f64,
    dec_compute: f64,
    dec_interfere: f64,
    dec_transfer: f64,
}

/// Stable per-GPU track ids, mirrored across pool flips (a flip moves
/// the drained tail instance between pools; see `ClusterState`).
#[derive(Debug, Clone, Default)]
struct ReplicaTracks {
    relaxed: Vec<usize>,
    strict: Vec<usize>,
}

/// An in-flight KV transfer job being rendered and attributed.
#[derive(Debug)]
struct TransferTrack {
    rid: RequestId,
    kind: TransferKind,
    /// `(link, lane)` once the first chunk order fixes the link.
    link_lane: Option<(usize, usize)>,
    flow: Option<u64>,
    /// The flow's "s" (or continuing "t") event was emitted.
    anchored: bool,
    /// A "t" step was emitted at the first chunk slice.
    stepped: bool,
}

/// One buffered Chrome trace event; durations stay patchable until
/// serialization (preemption truncates, crashes close down-windows).
#[derive(Debug, Clone)]
struct TraceEvent {
    ph: &'static str,
    name: String,
    cat: &'static str,
    pid: usize,
    tid: usize,
    ts_us: f64,
    dur_us: Option<f64>,
    /// Flow binding: `(flow id, bind to enclosing slice)`.
    flow: Option<(u64, bool)>,
    args: Vec<(&'static str, Json)>,
}

impl TraceEvent {
    fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("name", Json::Str(self.name.clone())),
            ("cat", Json::Str(self.cat.to_string())),
            ("ph", Json::Str(self.ph.to_string())),
            ("pid", Json::Num(self.pid as f64)),
            ("tid", Json::Num(self.tid as f64)),
            ("ts", Json::Num(self.ts_us)),
        ];
        if let Some(d) = self.dur_us {
            pairs.push(("dur", Json::Num(d)));
        }
        if let Some((id, bind)) = self.flow {
            pairs.push(("id", Json::Num(id as f64)));
            if bind {
                pairs.push(("bp", Json::Str("e".to_string())));
            }
        }
        if !self.args.is_empty() {
            let args: Vec<(&str, Json)> = self
                .args
                .iter()
                .map(|(k, v)| (*k, v.clone()))
                .collect();
            pairs.push(("args", Json::obj(args)));
        }
        Json::obj(pairs)
    }
}

fn step_label(kind: StepKind) -> &'static str {
    match kind {
        StepKind::PrefillOnline => "prefill-online",
        StepKind::PrefillOffline => "prefill-offline",
        StepKind::DecodeRelaxed => "decode-relaxed",
        StepKind::DecodeStrict => "decode-strict",
        StepKind::Composed => "composed",
        StepKind::Warm => "warm",
    }
}

fn key_of(replica: usize, inst: InstanceRef) -> (usize, u8, usize) {
    match inst {
        InstanceRef::Relaxed(i) => (replica, 0, i),
        InstanceRef::Strict(i) => (replica, 1, i),
    }
}

fn inst_of(key: (usize, u8, usize)) -> InstanceRef {
    if key.1 == 0 {
        InstanceRef::Relaxed(key.2)
    } else {
        InstanceRef::Strict(key.2)
    }
}

// ---------------------------------------------------------------- recorder

/// The action-stream tap. [`TraceRecorder::disabled`] (the executor
/// default) is a no-op whose every entry point is one branch;
/// [`TraceRecorder::flight`] records.
#[derive(Debug, Default)]
pub struct TraceRecorder {
    inner: Option<Box<FlightRecorder>>,
}

impl TraceRecorder {
    /// The zero-overhead default: observes nothing.
    pub fn disabled() -> Self {
        TraceRecorder { inner: None }
    }

    /// An enabled flight recorder.
    pub fn flight(opts: TelemetryOpts) -> Self {
        TraceRecorder {
            inner: Some(Box::new(FlightRecorder::new(opts))),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Declare the simulated horizon (trace duration + drain) so the
    /// `--progress` heartbeat can print percent-complete and an ETA.
    pub fn set_horizon(&mut self, horizon: f64) {
        if let Some(f) = &mut self.inner {
            f.horizon = horizon;
        }
    }

    /// Register workload statics (class, arrival, prompt/output lengths)
    /// before the run starts.
    pub fn register_requests(&mut self, requests: &[Request]) {
        if let Some(f) = &mut self.inner {
            f.register_requests(requests);
        }
    }

    /// Register `replica`'s initial pool topology; tracks stay stable
    /// across role flips.
    pub fn register_replica(&mut self, replica: usize, relaxed: usize, strict: usize) {
        if let Some(f) = &mut self.inner {
            f.register_replica(replica, relaxed, strict);
        }
    }

    /// Tap one action batch from `replica`'s core at virtual time `now`.
    #[inline]
    pub fn observe(&mut self, now: f64, replica: usize, actions: &[Action]) {
        if let Some(f) = &mut self.inner {
            let _p = obs::scope(Subsystem::Telemetry);
            f.observe(now, replica, actions);
        }
    }

    /// True when the gauge sampler's next tick is due.
    #[inline]
    pub fn sample_due(&self, now: f64) -> bool {
        match &self.inner {
            Some(f) => now >= f.next_sample,
            None => false,
        }
    }

    /// Sample one replica's gauges (call once per replica per due tick).
    pub fn sample_replica(
        &mut self,
        now: f64,
        replica: usize,
        cluster: &ClusterState,
        links: &[LinkState],
    ) {
        if let Some(f) = &mut self.inner {
            let _p = obs::scope(Subsystem::Telemetry);
            f.sample_replica(now, replica, cluster, links);
        }
    }

    /// Advance the sampling clock (after all replicas sampled) and emit
    /// the optional progress line. `events` is the executor's cumulative
    /// loop-event count, used for the heartbeat's events/s rate.
    pub fn sample_tick(&mut self, now: f64, events: u64) {
        if let Some(f) = &mut self.inner {
            let _p = obs::scope(Subsystem::Telemetry);
            f.sample_tick(now, events);
        }
    }

    /// Fold `r`'s final measured state in: chunk-span audit plus the
    /// TTFT/TPOT attribution row when `r` is a violated online request.
    pub fn finalize_request(&mut self, r: &Request) {
        if let Some(f) = &mut self.inner {
            let _p = obs::scope(Subsystem::Telemetry);
            f.finalize_request(r);
        }
    }

    /// Close remaining spans at `end_time` and build the outputs.
    /// Returns `None` for a disabled recorder.
    pub fn finish(&mut self, end_time: f64) -> Option<TelemetryOut> {
        let _p = obs::scope(Subsystem::Telemetry);
        self.inner.take().map(|mut f| f.finish(end_time))
    }

    /// Attach the streaming incident engine (DESIGN.md §3.12). No-op on a
    /// disabled recorder. The watchdog taps the same action stream and
    /// gauge ticks the recorder observes; its ledger comes back in
    /// [`TelemetryOut::incidents`].
    pub fn arm_watch(&mut self, watch: crate::watch::Watchdog) {
        if let Some(f) = &mut self.inner {
            f.watch = Some(Box::new(watch));
        }
    }
}

/// One gauge sample in plain-old-data form (DESIGN.md §3.13). The hot
/// sampling path appends a fixed-size row (link utilizations land in the
/// recorder's shared `util_store` pool) and the JSON timeline is
/// materialized once at export — with exactly the historical key order
/// and value formulas, so same-seed `--json-out` stays byte-identical.
#[derive(Debug)]
struct GaugeRow {
    t: f64,
    replica: usize,
    relaxed: usize,
    strict: usize,
    kv_used: usize,
    kv_cap: usize,
    online_queue: usize,
    offline_backlog: usize,
    running_steps: usize,
    down: usize,
    attainment: f64,
    /// Span of this row's link utilizations in `util_store`.
    util_start: usize,
    util_len: usize,
    actions: u64,
}

impl GaugeRow {
    fn to_json(&self, util_store: &[f64]) -> Json {
        let util =
            &util_store[self.util_start..self.util_start + self.util_len];
        Json::obj(vec![
            ("t", Json::Num(self.t)),
            ("replica", Json::Num(self.replica as f64)),
            ("relaxed", Json::Num(self.relaxed as f64)),
            ("strict", Json::Num(self.strict as f64)),
            ("kv_used_tokens", Json::Num(self.kv_used as f64)),
            ("kv_capacity_tokens", Json::Num(self.kv_cap as f64)),
            (
                "kv_used_frac",
                Json::Num(self.kv_used as f64 / self.kv_cap.max(1) as f64),
            ),
            ("online_queue", Json::Num(self.online_queue as f64)),
            ("offline_backlog", Json::Num(self.offline_backlog as f64)),
            ("running_steps", Json::Num(self.running_steps as f64)),
            ("down", Json::Num(self.down as f64)),
            ("slo_attainment", Json::Num(self.attainment)),
            ("link_utilization", Json::arr_f64(util)),
            ("actions", Json::Num(self.actions as f64)),
        ])
    }
}

#[derive(Debug)]
struct FlightRecorder {
    opts: TelemetryOpts,
    reqs: Vec<ReqTrack>,
    replicas: Vec<ReplicaTracks>,
    open_steps: BTreeMap<(usize, u8, usize), OpenStep>,
    /// Crash windows awaiting recovery: key → (event idx, start).
    open_down: BTreeMap<(usize, u8, usize), (Option<usize>, f64)>,
    transfers: BTreeMap<(usize, JobId), TransferTrack>,
    /// Lane occupancy per `(replica, link)`.
    lanes: BTreeMap<(usize, usize), Vec<bool>>,
    track_names: BTreeMap<(usize, usize), String>,
    events: Vec<TraceEvent>,
    next_flow: u64,
    /// Flow ids waiting for the rescued request's next step (or its
    /// next transfer hop, for offload → restore chains).
    pending_flow: BTreeMap<RequestId, u64>,
    next_sample: f64,
    last_sample_at: f64,
    samples: Vec<GaugeRow>,
    /// Shared pool of per-row link utilizations; each [`GaugeRow`] holds
    /// a span into it, so sampling never allocates per tick.
    util_store: Vec<f64>,
    /// Exact-replay mirror: the gauge timeline built the historical way
    /// (one JSON object per tick). [`FlightRecorder::finish`] asserts the
    /// flat log serializes identically.
    #[cfg(test)]
    replay: Vec<Json>,
    link_busy_prev: BTreeMap<(usize, usize), f64>,
    actions_seen: u64,
    online_finished: u64,
    online_violations_est: u64,
    /// Recent online completions `(finish time, met SLO)` for the
    /// sliding-window attainment gauge.
    window: VecDeque<(f64, bool)>,
    attr_rows: Vec<Json>,
    dominant_ttft: BTreeMap<&'static str, u64>,
    dominant_tpot: BTreeMap<&'static str, u64>,
    component_totals: BTreeMap<&'static str, f64>,
    audit: SpanAudit,
    started_wall: Instant,
    last_progress_wall: f64,
    last_progress_actions: u64,
    last_progress_t: f64,
    last_progress_events: u64,
    /// Simulated end time (trace duration + drain), used by the progress
    /// line's percent-complete and ETA estimates. 0 = unknown.
    horizon: f64,
    /// Streaming incident engine (DESIGN.md §3.12), armed via
    /// [`TraceRecorder::arm_watch`]. `None` = pure-observer recorder,
    /// byte-identical outputs to pre-watchdog builds.
    watch: Option<Box<crate::watch::Watchdog>>,
}

impl FlightRecorder {
    fn new(opts: TelemetryOpts) -> Self {
        FlightRecorder {
            opts,
            reqs: Vec::new(),
            replicas: Vec::new(),
            open_steps: BTreeMap::new(),
            open_down: BTreeMap::new(),
            transfers: BTreeMap::new(),
            lanes: BTreeMap::new(),
            track_names: BTreeMap::new(),
            events: Vec::new(),
            next_flow: 0,
            pending_flow: BTreeMap::new(),
            next_sample: 0.0,
            last_sample_at: 0.0,
            samples: Vec::new(),
            util_store: Vec::new(),
            #[cfg(test)]
            replay: Vec::new(),
            link_busy_prev: BTreeMap::new(),
            actions_seen: 0,
            online_finished: 0,
            online_violations_est: 0,
            window: VecDeque::new(),
            attr_rows: Vec::new(),
            dominant_ttft: BTreeMap::new(),
            dominant_tpot: BTreeMap::new(),
            component_totals: BTreeMap::new(),
            audit: SpanAudit::default(),
            started_wall: Instant::now(),
            last_progress_wall: 0.0,
            last_progress_actions: 0,
            last_progress_t: 0.0,
            last_progress_events: 0,
            horizon: 0.0,
            watch: None,
        }
    }

    fn register_requests(&mut self, requests: &[Request]) {
        let max_id = requests
            .iter()
            .map(|r| r.id as usize + 1)
            .max()
            .unwrap_or(0);
        if self.reqs.len() < max_id {
            self.reqs.resize(max_id, ReqTrack::default());
        }
        for r in requests {
            let t = &mut self.reqs[r.id as usize];
            t.online = r.class == Class::Online;
            t.arrival = r.arrival;
            t.prompt_len = r.prompt_len;
            t.output_len = r.output_len;
        }
        if let Some(w) = &mut self.watch {
            w.register_requests(requests);
        }
    }

    fn register_replica(&mut self, replica: usize, relaxed: usize, strict: usize) {
        if self.replicas.len() <= replica {
            self.replicas
                .resize(replica + 1, ReplicaTracks::default());
        }
        let rt = &mut self.replicas[replica];
        rt.relaxed = (0..relaxed).collect();
        rt.strict = (relaxed..relaxed + strict).collect();
        if let Some(w) = &mut self.watch {
            w.register_replica(replica, relaxed, strict);
        }
    }

    // ---------------------------------------------------------- plumbing

    fn push_event(&mut self, ev: TraceEvent) -> usize {
        self.events.push(ev);
        self.events.len() - 1
    }

    /// Perfetto thread id of `inst`'s stable per-GPU track; `None` (and
    /// an audit mark) when the reference is outside the topology.
    fn tid_of(&mut self, replica: usize, inst: InstanceRef) -> Option<usize> {
        let sid = match self.replicas.get(replica) {
            Some(rt) => match inst {
                InstanceRef::Relaxed(i) => rt.relaxed.get(i).copied(),
                InstanceRef::Strict(i) => rt.strict.get(i).copied(),
            },
            None => None,
        };
        match sid {
            Some(s) => {
                let tid = TID_INSTANCE_BASE + s;
                self.track_names
                    .entry((replica, tid))
                    .or_insert_with(|| format!("gpu{s}"));
                Some(tid)
            }
            None => {
                self.audit.dangling_instance_refs += 1;
                None
            }
        }
    }

    fn instant(
        &mut self,
        now: f64,
        replica: usize,
        inst: InstanceRef,
        name: String,
        cat: &'static str,
    ) {
        if !self.opts.perfetto {
            return;
        }
        if let Some(tid) = self.tid_of(replica, inst) {
            self.push_event(TraceEvent {
                ph: "i",
                name,
                cat,
                pid: replica,
                tid,
                ts_us: now * 1e6,
                dur_us: None,
                flow: None,
                args: vec![("s", Json::Str("t".to_string()))],
            });
        }
    }

    fn alloc_lane(&mut self, replica: usize, link: usize) -> usize {
        let lanes = self.lanes.entry((replica, link)).or_default();
        if let Some(i) = lanes.iter().position(|used| !*used) {
            lanes[i] = true;
            return i;
        }
        if lanes.len() < LANES_PER_LINK {
            lanes.push(true);
            lanes.len() - 1
        } else {
            LANES_PER_LINK - 1
        }
    }

    fn free_lane(&mut self, replica: usize, link: usize, lane: usize) {
        if let Some(lanes) = self.lanes.get_mut(&(replica, link)) {
            if lane < lanes.len() {
                lanes[lane] = false;
            }
        }
    }

    /// Decode-phase union-cursor accounting: the step `[start, end]`
    /// contributes `own` compute share, the rest interference.
    fn add_decode(t: &mut ReqTrack, start: f64, end: f64, own: f64) -> PartRef {
        let floor = t.first_token_est.unwrap_or(start);
        let s = start.max(t.dec_busy_until).max(floor);
        if end <= s {
            return PartRef::None;
        }
        let d = end - s;
        let c = d * own;
        t.dec_compute += c;
        t.dec_interfere += d - c;
        t.dec_busy_until = end;
        PartRef::Dec {
            eff_start: s,
            compute: c,
            interfere: d - c,
        }
    }

    /// Shorten an open step to `new_end`, patching its slice and every
    /// participant's attribution.
    fn truncate_step(&mut self, st: &mut OpenStep, new_end: f64) {
        let new_end = new_end.max(st.start);
        if new_end >= st.end {
            return;
        }
        let old_end = st.end;
        st.end = new_end;
        if let Some(i) = st.ev_idx {
            self.events[i].dur_us = Some((new_end - st.start) * 1e6);
        }
        for (rid, pr) in st.parts.iter_mut() {
            let t = &mut self.reqs[*rid as usize];
            match pr {
                PartRef::Pre(idx) => {
                    let iv = &mut t.pre_steps[*idx];
                    iv.end = new_end.max(iv.start);
                    if t.first_token_est == Some(old_end) {
                        t.first_token_est = Some(new_end);
                    }
                }
                PartRef::Dec {
                    eff_start,
                    compute,
                    interfere,
                } => {
                    let denom = old_end - *eff_start;
                    if denom > 0.0 {
                        let scale =
                            ((new_end - *eff_start).max(0.0) / denom).min(1.0);
                        let nc = *compute * scale;
                        let ni = *interfere * scale;
                        t.dec_compute += nc - *compute;
                        t.dec_interfere += ni - *interfere;
                        *compute = nc;
                        *interfere = ni;
                        if t.dec_busy_until == old_end {
                            t.dec_busy_until = new_end.max(*eff_start);
                        }
                    }
                }
                PartRef::None => {}
            }
        }
    }

    // ----------------------------------------------------------- observe

    fn observe(&mut self, now: f64, replica: usize, actions: &[Action]) {
        self.actions_seen += actions.len() as u64;
        if let Some(w) = &mut self.watch {
            w.on_actions(now, replica, actions);
        }
        for a in actions {
            match a {
                Action::StartStep {
                    inst,
                    kind,
                    participants,
                    prefill,
                    predicted_latency,
                    cached_tokens,
                    seq: _,
                } => self.on_start_step(
                    now,
                    replica,
                    *inst,
                    *kind,
                    participants,
                    prefill,
                    *predicted_latency,
                    *cached_tokens,
                ),
                Action::Preempt { inst, delay, seq: _ } => {
                    self.on_preempt(now, replica, *inst, *delay);
                }
                Action::Evict { inst, req } => {
                    self.on_evict(now, replica, *inst, *req);
                }
                Action::Migrate {
                    req, from_relaxed, ..
                } => {
                    self.instant(
                        now,
                        replica,
                        InstanceRef::Relaxed(*from_relaxed),
                        format!("migrate:{req}"),
                        "migrate",
                    );
                }
                Action::TransferStart {
                    job,
                    req,
                    kind,
                    kv_tokens,
                    chunks,
                } => self.on_transfer_start(
                    now, replica, *job, *req, *kind, *kv_tokens, *chunks,
                ),
                Action::TransferChunk {
                    job,
                    req,
                    link,
                    chunk,
                    predicted_latency,
                    seq: _,
                } => self.on_transfer_chunk(
                    now,
                    replica,
                    *job,
                    *req,
                    *link,
                    *chunk,
                    *predicted_latency,
                ),
                Action::TransferDone { job, req, kind } => {
                    self.on_transfer_done(replica, *job, *req, *kind);
                }
                Action::TransferCancel { job, req: _ } => {
                    if let Some(tt) = self.transfers.remove(&(replica, *job)) {
                        if let Some((link, lane)) = tt.link_lane {
                            self.free_lane(replica, link, lane);
                        }
                    }
                }
                Action::Admit { inst, req } => {
                    if (*req as usize) < self.reqs.len() {
                        let t = &mut self.reqs[*req as usize];
                        if t.admitted_at.is_none() {
                            t.admitted_at = Some(now);
                        }
                        t.home = Some((replica, 0, *inst));
                    }
                }
                Action::PrefixResolve { inst, req, .. } => {
                    self.on_prefix_resolve(now, replica, *inst, *req);
                }
                Action::PrefixEvict { .. } => {}
                Action::RepartitionPlan {
                    epoch,
                    relaxed_target,
                    strict_target,
                    ..
                } => {
                    if self.opts.perfetto {
                        self.track_names
                            .entry((replica, TID_POOL_MANAGER))
                            .or_insert_with(|| "pool-manager".to_string());
                        self.push_event(TraceEvent {
                            ph: "i",
                            name: format!(
                                "plan#{epoch}:{relaxed_target}r/{strict_target}s"
                            ),
                            cat: "pool",
                            pid: replica,
                            tid: TID_POOL_MANAGER,
                            ts_us: now * 1e6,
                            dur_us: None,
                            flow: None,
                            args: vec![("s", Json::Str("t".to_string()))],
                        });
                    }
                }
                Action::RoleChange { phase, inst, to } => {
                    self.on_role_change(now, replica, *phase, *inst, *to);
                }
                Action::Complete { req } => self.on_complete(now, *req),
                Action::InstanceDown { inst } => {
                    self.on_instance_down(now, replica, *inst);
                }
                Action::InstanceUp { inst } => {
                    self.on_instance_up(now, replica, *inst);
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn on_start_step(
        &mut self,
        now: f64,
        replica: usize,
        inst: InstanceRef,
        kind: StepKind,
        participants: &[RequestId],
        prefill: &[crate::instance::PrefillSegment],
        predicted_latency: f64,
        cached_tokens: usize,
    ) {
        let key = key_of(replica, inst);
        if let Some(prev) = self.open_steps.remove(&key) {
            if now < prev.end - 1e-6 {
                self.audit.monotone_violations += 1;
            }
            self.audit.closed_spans += 1;
        }
        self.audit.opened_spans += 1;
        let end = now + predicted_latency;

        let mut total: f64 = 0.0;
        match kind {
            StepKind::PrefillOnline | StepKind::PrefillOffline => {
                // Exclusive steps carry no per-request token counts;
                // weight attribution shares by prompt length.
                for &rid in participants {
                    if let Some(t) = self.reqs.get(rid as usize) {
                        total += t.prompt_len.max(1) as f64;
                    }
                }
            }
            StepKind::Composed => {
                total += participants.len() as f64;
                for seg in prefill {
                    total += seg.tokens as f64;
                }
            }
            StepKind::DecodeRelaxed | StepKind::DecodeStrict => {
                total = participants.len() as f64;
            }
            StepKind::Warm => {}
        }
        let total = total.max(1.0);

        let mut parts: Vec<(RequestId, PartRef)> = Vec::new();
        match kind {
            StepKind::PrefillOnline | StepKind::PrefillOffline => {
                for &rid in participants {
                    if (rid as usize) >= self.reqs.len() {
                        continue;
                    }
                    let t = &mut self.reqs[rid as usize];
                    let own = t.prompt_len.max(1) as f64 / total;
                    // The whole uncached remainder runs in this one
                    // step — there are no chunk segments to audit.
                    t.exclusive_prefill = true;
                    let pr = if t.online && t.first_token_est.is_none() {
                        t.pre_steps.push(StepInterval {
                            start: now,
                            end,
                            own,
                        });
                        PartRef::Pre(t.pre_steps.len() - 1)
                    } else {
                        PartRef::None
                    };
                    parts.push((rid, pr));
                    // Prefill completes at this step's end; online
                    // requests emit their first token there.
                    if t.first_token_est.is_none() {
                        t.first_token_est = Some(end);
                    }
                    t.home = Some(key);
                }
            }
            StepKind::Composed => {
                for seg in prefill {
                    let rid = seg.req;
                    if (rid as usize) >= self.reqs.len() {
                        continue;
                    }
                    let own = seg.tokens as f64 / total;
                    let t = &mut self.reqs[rid as usize];
                    t.prefill_credit += seg.tokens as i64;
                    let pr = if t.online && t.first_token_est.is_none() {
                        t.pre_steps.push(StepInterval {
                            start: now,
                            end,
                            own,
                        });
                        PartRef::Pre(t.pre_steps.len() - 1)
                    } else {
                        PartRef::None
                    };
                    parts.push((rid, pr));
                    if seg.last && t.first_token_est.is_none() {
                        t.first_token_est = Some(end);
                    }
                    t.home = Some(key);
                }
                for &rid in participants {
                    if (rid as usize) >= self.reqs.len() {
                        continue;
                    }
                    let own = 1.0 / total;
                    let t = &mut self.reqs[rid as usize];
                    let pr = if t.online
                        && t.finished_est.is_none()
                        && t.first_token_est.is_some()
                    {
                        Self::add_decode(t, now, end, own)
                    } else {
                        PartRef::None
                    };
                    parts.push((rid, pr));
                    t.home = Some(key);
                }
            }
            StepKind::DecodeRelaxed | StepKind::DecodeStrict => {
                for &rid in participants {
                    if (rid as usize) >= self.reqs.len() {
                        continue;
                    }
                    let own = 1.0 / total;
                    let t = &mut self.reqs[rid as usize];
                    let pr = if t.online && t.finished_est.is_none() {
                        Self::add_decode(t, now, end, own)
                    } else {
                        PartRef::None
                    };
                    parts.push((rid, pr));
                    t.home = Some(key);
                }
            }
            StepKind::Warm => {}
        }

        let ev_idx = if self.opts.perfetto {
            self.tid_of(replica, inst).map(|tid| {
                let prefill_tokens: usize =
                    prefill.iter().map(|s| s.tokens).sum();
                // Pending flow arrows land on the rescued request's
                // next step: the "f" end anchors inside this slice.
                let mut flows: Vec<u64> = Vec::new();
                for (rid, _) in &parts {
                    if let Some(fid) = self.pending_flow.remove(rid) {
                        flows.push(fid);
                    }
                }
                let idx = self.push_event(TraceEvent {
                    ph: "X",
                    name: step_label(kind).to_string(),
                    cat: "step",
                    pid: replica,
                    tid,
                    ts_us: now * 1e6,
                    dur_us: Some(predicted_latency * 1e6),
                    flow: None,
                    args: vec![
                        (
                            "participants",
                            Json::Num(participants.len() as f64),
                        ),
                        ("prefill_tokens", Json::Num(prefill_tokens as f64)),
                        ("cached_tokens", Json::Num(cached_tokens as f64)),
                    ],
                });
                for fid in flows {
                    self.push_event(TraceEvent {
                        ph: "f",
                        name: "kv-flow".to_string(),
                        cat: "flow",
                        pid: replica,
                        tid,
                        ts_us: now * 1e6,
                        dur_us: None,
                        flow: Some((fid, true)),
                        args: Vec::new(),
                    });
                }
                idx
            })
        } else {
            None
        };

        self.open_steps.insert(
            key,
            OpenStep {
                ev_idx,
                start: now,
                end,
                kind,
                parts,
            },
        );
    }

    fn on_preempt(&mut self, now: f64, replica: usize, inst: usize, delay: f64) {
        let key = (replica, 0u8, inst);
        if let Some(mut st) = self.open_steps.remove(&key) {
            self.truncate_step(&mut st, now + delay);
            if matches!(st.kind, StepKind::PrefillOffline) {
                // Exclusive-mode offline prefill work is discarded at
                // the truncated step's end and the requests requeue for
                // recompute without an `Evict` — reset their audit
                // state here so the fresh attempt starts clean.
                for &(rid, _) in &st.parts {
                    let t = &mut self.reqs[rid as usize];
                    t.prefill_credit = 0;
                    t.exclusive_prefill = false;
                    if t.first_token_est.is_some_and(|e| e > now - EPS) {
                        t.first_token_est = None;
                    }
                }
            }
            self.open_steps.insert(key, st);
        }
        self.instant(
            now,
            replica,
            InstanceRef::Relaxed(inst),
            "preempt".to_string(),
            "preempt",
        );
    }

    fn on_evict(&mut self, now: f64, replica: usize, inst: InstanceRef, rid: RequestId) {
        if (rid as usize) < self.reqs.len() {
            let t = &mut self.reqs[rid as usize];
            t.evictions += 1;
            // KV dropped: the final prefill pass restarts from zero
            // (minus whatever the prefix cache still serves).
            t.prefill_credit = 0;
            t.exclusive_prefill = false;
            if t.first_token_est.is_some_and(|e| e > now - EPS) {
                t.first_token_est = None;
            }
            t.home = None;
        }
        self.instant(now, replica, inst, format!("evict:{rid}"), "evict");
    }

    #[allow(clippy::too_many_arguments)]
    fn on_transfer_start(
        &mut self,
        now: f64,
        replica: usize,
        job: JobId,
        rid: RequestId,
        kind: TransferKind,
        kv_tokens: usize,
        chunks: usize,
    ) {
        let mut anchored = false;
        let flow = if self.opts.perfetto {
            // Continue an existing chain (offload → restore) or open a
            // new one.
            let (fid, cont) = match self.pending_flow.remove(&rid) {
                Some(id) => (id, true),
                None => {
                    self.next_flow += 1;
                    (self.next_flow, false)
                }
            };
            let home = self
                .reqs
                .get(rid as usize)
                .and_then(|t| t.home);
            if let Some(hkey) = home {
                if let Some(tid) = self.tid_of(hkey.0, inst_of(hkey)) {
                    // A zero-duration marker slice hosts the flow's
                    // departure anchor on the source instance track.
                    self.push_event(TraceEvent {
                        ph: "X",
                        name: format!("{}:{}", kind.name(), rid),
                        cat: "transfer",
                        pid: hkey.0,
                        tid,
                        ts_us: now * 1e6,
                        dur_us: Some(0.0),
                        flow: None,
                        args: vec![
                            ("kv_tokens", Json::Num(kv_tokens as f64)),
                            ("chunks", Json::Num(chunks as f64)),
                        ],
                    });
                    self.push_event(TraceEvent {
                        ph: if cont { "t" } else { "s" },
                        name: "kv-flow".to_string(),
                        cat: "flow",
                        pid: hkey.0,
                        tid,
                        ts_us: now * 1e6,
                        dur_us: None,
                        flow: Some((fid, false)),
                        args: Vec::new(),
                    });
                    anchored = true;
                }
            }
            Some(fid)
        } else {
            None
        };
        self.transfers.insert(
            (replica, job),
            TransferTrack {
                rid,
                kind,
                link_lane: None,
                flow,
                anchored,
                stepped: false,
            },
        );
    }

    #[allow(clippy::too_many_arguments)]
    fn on_transfer_chunk(
        &mut self,
        now: f64,
        replica: usize,
        job: JobId,
        rid: RequestId,
        link: usize,
        chunk: usize,
        predicted_latency: f64,
    ) {
        let mut tt = match self.transfers.remove(&(replica, job)) {
            Some(t) => t,
            None => return,
        };
        if tt.link_lane.is_none() {
            let lane = self.alloc_lane(replica, link);
            tt.link_lane = Some((link, lane));
        }
        if self.opts.perfetto {
            let (_, lane) = tt.link_lane.unwrap_or((link, 0));
            let tid = TID_LANE_BASE + link * LANES_PER_LINK + lane;
            self.track_names
                .entry((replica, tid))
                .or_insert_with(|| format!("xfer link{link} lane{lane}"));
            self.push_event(TraceEvent {
                ph: "X",
                name: format!("{}:{}#{}", tt.kind.name(), rid, chunk),
                cat: "transfer",
                pid: replica,
                tid,
                ts_us: now * 1e6,
                dur_us: Some(predicted_latency * 1e6),
                flow: None,
                args: Vec::new(),
            });
            if let Some(fid) = tt.flow {
                if !tt.anchored {
                    self.push_event(TraceEvent {
                        ph: "s",
                        name: "kv-flow".to_string(),
                        cat: "flow",
                        pid: replica,
                        tid,
                        ts_us: now * 1e6,
                        dur_us: None,
                        flow: Some((fid, false)),
                        args: Vec::new(),
                    });
                    tt.anchored = true;
                } else if !tt.stepped {
                    self.push_event(TraceEvent {
                        ph: "t",
                        name: "kv-flow".to_string(),
                        cat: "flow",
                        pid: replica,
                        tid,
                        ts_us: now * 1e6,
                        dur_us: None,
                        flow: Some((fid, false)),
                        args: Vec::new(),
                    });
                    tt.stepped = true;
                }
            }
        }
        if (rid as usize) < self.reqs.len() {
            let t = &mut self.reqs[rid as usize];
            if t.online {
                if t.first_token_est.is_none() {
                    t.pre_transfers.push((now, now + predicted_latency));
                } else if t.finished_est.is_none() {
                    let s = now.max(t.dec_busy_until);
                    let e = now + predicted_latency;
                    if e > s {
                        t.dec_transfer += e - s;
                        t.dec_busy_until = e;
                    }
                }
            }
        }
        self.transfers.insert((replica, job), tt);
    }

    fn on_transfer_done(
        &mut self,
        replica: usize,
        job: JobId,
        rid: RequestId,
        kind: TransferKind,
    ) {
        if let Some(tt) = self.transfers.remove(&(replica, job)) {
            if let Some((link, lane)) = tt.link_lane {
                self.free_lane(replica, link, lane);
            }
            if let Some(fid) = tt.flow {
                self.pending_flow.insert(rid, fid);
            }
        }
        if (rid as usize) < self.reqs.len() {
            self.reqs[rid as usize].home = match kind {
                TransferKind::Dispatch { to_strict }
                | TransferKind::Migrate { to_strict } => {
                    Some((replica, 1, to_strict))
                }
                TransferKind::Rescue { to_relaxed }
                | TransferKind::Restore { to_relaxed } => {
                    Some((replica, 0, to_relaxed))
                }
                TransferKind::Offload => None,
            };
        }
    }

    /// A prefix-cache lookup marks admission: the request has a home
    /// from here on. (Cached-token credit is *not* tracked from this
    /// action — the chunk audit compares announced segment tokens
    /// against the measured `prefill_target - prefill_cached`, so the
    /// cached share never enters the recorder's books.)
    fn on_prefix_resolve(
        &mut self,
        now: f64,
        replica: usize,
        inst: InstanceRef,
        rid: RequestId,
    ) {
        if (rid as usize) >= self.reqs.len() {
            return;
        }
        let key = key_of(replica, inst);
        let t = &mut self.reqs[rid as usize];
        if t.admitted_at.is_none() {
            t.admitted_at = Some(now);
        }
        t.home = Some(key);
    }

    fn on_role_change(
        &mut self,
        now: f64,
        replica: usize,
        phase: RolePhase,
        inst: InstanceRef,
        to: PoolRole,
    ) {
        if matches!(phase, RolePhase::Flip) {
            if let Some(rt) = self.replicas.get_mut(replica) {
                // Mirror `ClusterState`: a flip moves the drained tail
                // instance; everyone else's pool index is unchanged.
                match to {
                    PoolRole::Strict => {
                        if let Some(s) = rt.relaxed.pop() {
                            rt.strict.push(s);
                        }
                    }
                    PoolRole::Relaxed => {
                        if let Some(s) = rt.strict.pop() {
                            rt.relaxed.push(s);
                        }
                    }
                }
            }
        }
        let label = match phase {
            RolePhase::Drain => "drain",
            RolePhase::Flip => "flip",
            RolePhase::Warm => "warm-up",
        };
        self.instant(
            now,
            replica,
            inst,
            format!("{label}\u{2192}{}", to.name()),
            "role",
        );
    }

    fn on_complete(&mut self, now: f64, rid: RequestId) {
        if (rid as usize) >= self.reqs.len() {
            return;
        }
        let (online, arrival, output_len, ft) = {
            let t = &mut self.reqs[rid as usize];
            t.finished_est = Some(now);
            (t.online, t.arrival, t.output_len, t.first_token_est)
        };
        if online {
            self.online_finished += 1;
            let (ttft_ok, tpot_ok) = match ft {
                Some(f) => {
                    let ttft_ok = f - arrival <= self.opts.slo.ttft + EPS;
                    let tpot_ok = if output_len > 1 {
                        (now - f) / (output_len as f64 - 1.0)
                            <= self.opts.slo.tpot + EPS
                    } else {
                        true
                    };
                    (ttft_ok, tpot_ok)
                }
                None => (false, false),
            };
            let ok = ttft_ok && tpot_ok;
            if !ok {
                self.online_violations_est += 1;
            }
            self.window.push_back((now, ok));
            if let Some(w) = &mut self.watch {
                w.on_online_complete(now, ttft_ok, tpot_ok);
            }
        }
        while let Some(&(ts, _)) = self.window.front() {
            if ts < now - ATTAINMENT_WINDOW_S {
                self.window.pop_front();
            } else {
                break;
            }
        }
    }

    fn on_instance_down(&mut self, now: f64, replica: usize, inst: InstanceRef) {
        let key = key_of(replica, inst);
        if let Some(mut st) = self.open_steps.remove(&key) {
            self.truncate_step(&mut st, now);
            // The crash kills the step: close its span here (the
            // forced evictions arrive as explicit `Evict` actions,
            // which reset the victims' chunk-audit state).
            self.audit.closed_spans += 1;
        }
        self.instant(now, replica, inst, "crash".to_string(), "fault");
        let ev = if self.opts.perfetto {
            self.tid_of(replica, inst).map(|tid| {
                self.push_event(TraceEvent {
                    ph: "X",
                    name: "down".to_string(),
                    cat: "fault",
                    pid: replica,
                    tid,
                    ts_us: now * 1e6,
                    dur_us: Some(0.0),
                    flow: None,
                    args: Vec::new(),
                })
            })
        } else {
            None
        };
        self.open_down.insert(key, (ev, now));
    }

    fn on_instance_up(&mut self, now: f64, replica: usize, inst: InstanceRef) {
        let key = key_of(replica, inst);
        if let Some((Some(idx), start)) = self.open_down.remove(&key) {
            self.events[idx].dur_us = Some((now - start).max(0.0) * 1e6);
        }
        self.instant(now, replica, inst, "up".to_string(), "fault");
    }

    // ------------------------------------------------------------ gauges

    fn sample_replica(
        &mut self,
        now: f64,
        replica: usize,
        cluster: &ClusterState,
        links: &[LinkState],
    ) {
        let mut kv_used = 0usize;
        let mut kv_cap = 0usize;
        let mut queue = 0usize;
        let mut running = 0usize;
        let mut down = 0usize;
        for inst in cluster.relaxed.iter().chain(cluster.strict.iter()) {
            kv_cap += inst.kv.capacity_tokens();
            kv_used += inst.kv.capacity_tokens() - inst.kv.free_tokens();
            queue += inst.online_queue.len() + inst.waiting_for_space.len();
            if inst.step.is_some() {
                running += 1;
            }
            if inst.down {
                down += 1;
            }
        }
        let dt = now - self.last_sample_at;
        let mut util = Vec::with_capacity(links.len());
        for (i, l) in links.iter().enumerate() {
            let prev = self
                .link_busy_prev
                .get(&(replica, i))
                .copied()
                .unwrap_or(0.0);
            let u = if dt > 0.0 {
                ((l.busy_s - prev) / dt).clamp(0.0, 1.0)
            } else {
                0.0
            };
            self.link_busy_prev.insert((replica, i), l.busy_s);
            util.push(u);
        }
        let att = self.attainment();
        if let Some(w) = &mut self.watch {
            w.on_sample(now, replica, cluster, links);
        }
        let util_start = self.util_store.len();
        self.util_store.extend_from_slice(&util);
        self.samples.push(GaugeRow {
            t: now,
            replica,
            relaxed: cluster.relaxed.len(),
            strict: cluster.strict.len(),
            kv_used,
            kv_cap,
            online_queue: queue,
            offline_backlog: cluster.offline_backlog.len(),
            running_steps: running,
            down,
            attainment: att,
            util_start,
            util_len: util.len(),
            actions: self.actions_seen,
        });
        #[cfg(test)]
        self.replay.push(Json::obj(vec![
            ("t", Json::Num(now)),
            ("replica", Json::Num(replica as f64)),
            ("relaxed", Json::Num(cluster.relaxed.len() as f64)),
            ("strict", Json::Num(cluster.strict.len() as f64)),
            ("kv_used_tokens", Json::Num(kv_used as f64)),
            ("kv_capacity_tokens", Json::Num(kv_cap as f64)),
            (
                "kv_used_frac",
                Json::Num(kv_used as f64 / kv_cap.max(1) as f64),
            ),
            ("online_queue", Json::Num(queue as f64)),
            (
                "offline_backlog",
                Json::Num(cluster.offline_backlog.len() as f64),
            ),
            ("running_steps", Json::Num(running as f64)),
            ("down", Json::Num(down as f64)),
            ("slo_attainment", Json::Num(att)),
            ("link_utilization", Json::arr_f64(&util)),
            ("actions", Json::Num(self.actions_seen as f64)),
        ]));
        if self.opts.perfetto {
            let counters: Vec<(&'static str, f64)> = vec![
                ("pool.relaxed", cluster.relaxed.len() as f64),
                ("pool.strict", cluster.strict.len() as f64),
                (
                    "kv.used_frac",
                    kv_used as f64 / kv_cap.max(1) as f64,
                ),
                ("queue.online", queue as f64),
                (
                    "queue.backlog",
                    cluster.offline_backlog.len() as f64,
                ),
                ("slo.attainment", att),
            ];
            for (name, v) in counters {
                self.push_event(TraceEvent {
                    ph: "C",
                    name: name.to_string(),
                    cat: "gauge",
                    pid: replica,
                    tid: 0,
                    ts_us: now * 1e6,
                    dur_us: None,
                    flow: None,
                    args: vec![("value", Json::Num(v))],
                });
            }
            for (i, u) in util.iter().enumerate() {
                self.push_event(TraceEvent {
                    ph: "C",
                    name: format!("link{i}.util"),
                    cat: "gauge",
                    pid: replica,
                    tid: 0,
                    ts_us: now * 1e6,
                    dur_us: None,
                    flow: None,
                    args: vec![("value", Json::Num(*u))],
                });
            }
        }
    }

    fn attainment(&self) -> f64 {
        if self.window.is_empty() {
            return 1.0;
        }
        let ok = self.window.iter().filter(|(_, ok)| *ok).count();
        ok as f64 / self.window.len() as f64
    }

    fn sample_tick(&mut self, now: f64, events: u64) {
        self.last_sample_at = now;
        self.next_sample = now + self.opts.sample_interval_s;
        if let Some(w) = &mut self.watch {
            w.on_tick(now);
        }
        if self.opts.progress {
            let wall = self.started_wall.elapsed().as_secs_f64();
            let dw = (wall - self.last_progress_wall).max(1e-9);
            let da = self.actions_seen - self.last_progress_actions;
            let de = events.saturating_sub(self.last_progress_events);
            // Sim-seconds advanced per wall-second since the last line;
            // the ETA divides the remaining horizon by this rate.
            let sim_rate = (now - self.last_progress_t).max(0.0) / dw;
            let mut line = format!(
                "[ooco] t={:.1}s events={} ({:.0}/s wall) actions={} ({:.0}/s wall) sim_rate={:.0}x slo_window={:.4}",
                now,
                events,
                de as f64 / dw,
                self.actions_seen,
                da as f64 / dw,
                sim_rate,
                self.attainment(),
            );
            if self.horizon > 0.0 {
                let pct = (now / self.horizon * 100.0).min(100.0);
                line.push_str(&format!(" {pct:.0}%"));
                if sim_rate > EPS && now < self.horizon {
                    let eta = (self.horizon - now) / sim_rate;
                    line.push_str(&format!(" eta={eta:.0}s"));
                }
            }
            eprintln!("{line}");
            self.last_progress_wall = wall;
            self.last_progress_actions = self.actions_seen;
            self.last_progress_t = now;
            self.last_progress_events = events;
        }
    }

    // ------------------------------------------------------ finalization

    fn finalize_request(&mut self, r: &Request) {
        let rid = r.id as usize;
        if rid >= self.reqs.len() {
            return;
        }
        // Chunk-span audit (§3.8 conservation, recorder view): a request
        // whose final prefill pass ran as composed chunk segments must
        // have those segments sum exactly to the measured uncached
        // remainder. Exclusive-mode prefills announce no segments and
        // are skipped — the cursor audit in the core covers them.
        if r.finished_at.is_some()
            && r.generated >= r.output_len
            && r.prefill_target > 0
        {
            let t = &self.reqs[rid];
            if !t.exclusive_prefill && t.prefill_credit > 0 {
                self.audit.chunk_audited += 1;
                let owed =
                    r.prefill_target as i64 - r.prefill_cached as i64;
                if t.prefill_credit != owed {
                    self.audit.chunk_mismatches += 1;
                }
            }
        }
        if r.class != Class::Online {
            return;
        }
        let rec = RequestRecord::from_request(r);
        if !rec.violates(&self.opts.slo) {
            return;
        }

        let slo = self.opts.slo;
        let ttft = r.ttft();
        let tpot = r.avg_tpot();
        let ttft_violated = match ttft {
            Some(t) => t > slo.ttft,
            None => true,
        };
        let tpot_violated =
            r.finished_at.is_none() || tpot.is_some_and(|t| t > slo.tpot);

        // ---- TTFT decomposition over [arrival, first token] ----
        let mut ttft_comp: Option<[f64; 4]> = None;
        if let (Some(ft), Some(_)) = (r.first_token_at, ttft) {
            let t = &self.reqs[rid];
            let w0 = t.arrival;
            let w1 = ft;
            let mut merged: Vec<(f64, f64)> = Vec::new();
            let mut compute = 0.0;
            let mut interfere = 0.0;
            let mut cursor = w0;
            for iv in &t.pre_steps {
                let s = iv.start.max(cursor).min(w1);
                let e = iv.end.min(w1).max(s);
                if e > s {
                    compute += (e - s) * iv.own;
                    interfere += (e - s) * (1.0 - iv.own);
                    merged.push((s, e));
                    cursor = e;
                }
            }
            let mut stall = 0.0;
            let mut tcur = w0;
            for &(s0, e0) in &t.pre_transfers {
                let s = s0.max(tcur).min(w1);
                let e = e0.min(w1).max(s);
                if e <= s {
                    continue;
                }
                tcur = e;
                let mut covered = 0.0;
                for &(ms, me) in &merged {
                    if me <= s {
                        continue;
                    }
                    if ms >= e {
                        break;
                    }
                    covered += me.min(e) - ms.max(s);
                }
                stall += (e - s) - covered;
            }
            let queueing = (w1 - w0) - compute - interfere - stall;
            let resid =
                ((compute + interfere + stall + queueing) - (w1 - w0)).abs();
            self.audit.max_attr_residual =
                self.audit.max_attr_residual.max(resid);
            ttft_comp = Some([queueing, stall, interfere, compute]);
        }

        // ---- TPOT decomposition over [first token, completion] ----
        let mut tpot_comp: Option<[f64; 4]> = None;
        if let (Some(ft), Some(fin)) = (r.first_token_at, r.finished_at) {
            if r.output_len > 1 {
                let t = &self.reqs[rid];
                let n = (r.output_len - 1) as f64;
                let window = fin - ft;
                let busy = t.dec_compute + t.dec_interfere + t.dec_transfer;
                let queueing = window - busy;
                tpot_comp = Some([
                    queueing / n,
                    t.dec_transfer / n,
                    t.dec_interfere / n,
                    t.dec_compute / n,
                ]);
            }
        }

        const CAUSES: [&str; 4] =
            ["queueing", "transfer_stall", "chunk_interference", "compute"];
        let dominant_of = |c: &[f64; 4]| -> &'static str {
            let mut best = 0;
            for i in 1..4 {
                if c[i] > c[best] {
                    best = i;
                }
            }
            CAUSES[best]
        };
        let comp_json = |c: &[f64; 4]| {
            Json::obj(vec![
                ("queueing", Json::Num(c[0])),
                ("transfer_stall", Json::Num(c[1])),
                ("chunk_interference", Json::Num(c[2])),
                ("compute", Json::Num(c[3])),
                ("sum", Json::Num(c.iter().sum())),
            ])
        };

        let dominant = match (ttft_violated, &ttft_comp, &tpot_comp) {
            (true, Some(c), _) => Some(dominant_of(c)),
            (false, _, Some(c)) if tpot_violated => Some(dominant_of(c)),
            _ => None,
        };
        if let Some(cause) = dominant {
            let at = r
                .finished_at
                .or(self.reqs[rid].finished_est)
                .unwrap_or(r.arrival);
            if let Some(w) = &mut self.watch {
                w.on_attributed(at, cause);
            }
        }
        if ttft_violated {
            if let Some(c) = &ttft_comp {
                *self
                    .dominant_ttft
                    .entry(dominant_of(c))
                    .or_insert(0) += 1;
                for (i, name) in CAUSES.iter().enumerate() {
                    *self.component_totals.entry(*name).or_insert(0.0) += c[i];
                }
            }
        }
        if tpot_violated {
            if let Some(c) = &tpot_comp {
                *self
                    .dominant_tpot
                    .entry(dominant_of(c))
                    .or_insert(0) += 1;
            }
        }

        let row = Json::obj(vec![
            ("id", Json::Num(r.id as f64)),
            (
                "ttft",
                ttft.map(Json::Num).unwrap_or(Json::Null),
            ),
            (
                "tpot",
                tpot.map(Json::Num).unwrap_or(Json::Null),
            ),
            ("finished", Json::Bool(r.finished_at.is_some())),
            ("ttft_violated", Json::Bool(ttft_violated)),
            ("tpot_violated", Json::Bool(tpot_violated)),
            (
                "evictions",
                Json::Num(self.reqs[rid].evictions as f64),
            ),
            (
                "ttft_components",
                ttft_comp
                    .as_ref()
                    .map(comp_json)
                    .unwrap_or(Json::Null),
            ),
            (
                "tpot_components",
                tpot_comp
                    .as_ref()
                    .map(comp_json)
                    .unwrap_or(Json::Null),
            ),
            (
                "dominant",
                dominant
                    .map(|d| Json::Str(d.to_string()))
                    .unwrap_or(Json::Null),
            ),
        ]);
        self.attr_rows.push(row);
        self.audit.attribution_rows += 1;
    }

    fn finish(&mut self, end_time: f64) -> TelemetryOut {
        let keys: Vec<_> = self.open_steps.keys().copied().collect();
        for k in keys {
            if let Some(mut st) = self.open_steps.remove(&k) {
                self.truncate_step(&mut st, end_time);
                self.audit.force_closed_spans += 1;
            }
        }
        let downs: Vec<_> = self.open_down.keys().copied().collect();
        for k in downs {
            if let Some((Some(idx), start)) = self.open_down.remove(&k) {
                self.events[idx].dur_us =
                    Some((end_time - start).max(0.0) * 1e6);
            }
        }
        self.pending_flow.clear();

        // Close the incident engine's books and draw its ledger as a
        // dedicated annotation track (one `incidents` thread per replica
        // process, TID_WATCHDOG).
        let watch_out = self.watch.take().map(|mut w| w.finish(end_time));
        if self.opts.perfetto {
            if let Some(wo) = &watch_out {
                for inc in &wo.incidents {
                    let pid = inc.replica.unwrap_or(0);
                    self.track_names
                        .entry((pid, TID_WATCHDOG))
                        .or_insert_with(|| "incidents".to_string());
                    self.events.push(TraceEvent {
                        ph: "X",
                        name: format!(
                            "{}:{}",
                            inc.kind.as_str(),
                            inc.cause
                        ),
                        cat: "incident",
                        pid,
                        tid: TID_WATCHDOG,
                        ts_us: inc.opened_at * 1e6,
                        dur_us: Some(inc.duration_s(end_time) * 1e6),
                        flow: None,
                        args: vec![
                            (
                                "severity",
                                Json::Str(
                                    inc.severity.as_str().to_string(),
                                ),
                            ),
                            (
                                "bottleneck",
                                Json::Str(inc.bottleneck.clone()),
                            ),
                            ("peak", Json::Num(inc.peak)),
                        ],
                    });
                }
            }
        }

        let ranked = |m: &BTreeMap<&'static str, u64>| {
            let mut v: Vec<(&str, u64)> =
                m.iter().map(|(k, c)| (*k, *c)).collect();
            v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
            Json::Arr(
                v.into_iter()
                    .map(|(k, c)| {
                        Json::obj(vec![
                            ("cause", Json::Str(k.to_string())),
                            ("count", Json::Num(c as f64)),
                        ])
                    })
                    .collect(),
            )
        };
        let totals = Json::Obj(
            self.component_totals
                .iter()
                .map(|(k, v)| (k.to_string(), Json::Num(*v)))
                .collect(),
        );
        let violations = self.attr_rows.len();
        let attribution = Json::obj(vec![
            (
                "requests",
                Json::Arr(std::mem::take(&mut self.attr_rows)),
            ),
            ("violations", Json::Num(violations as f64)),
            (
                "online_finished",
                Json::Num(self.online_finished as f64),
            ),
            ("ranked_ttft_causes", ranked(&self.dominant_ttft)),
            ("ranked_tpot_causes", ranked(&self.dominant_tpot)),
            ("component_totals_s", totals),
            (
                "max_residual",
                Json::Num(self.audit.max_attr_residual),
            ),
        ]);
        let util_store = std::mem::take(&mut self.util_store);
        let rows = std::mem::take(&mut self.samples);
        let timeline =
            Json::Arr(rows.iter().map(|r| r.to_json(&util_store)).collect());
        #[cfg(test)]
        {
            // Exact-replay equivalence: the flat log must serialize
            // byte-identically to the per-tick JSON it replaced. Every
            // unit test that finishes a sampled recorder re-proves this.
            let replay = Json::Arr(std::mem::take(&mut self.replay));
            assert_eq!(
                timeline.to_string(),
                replay.to_string(),
                "flat gauge log diverged from per-tick JSON replay"
            );
        }

        let perfetto = if self.opts.perfetto {
            let mut evs: Vec<Json> = Vec::new();
            for (r, _) in self.replicas.iter().enumerate() {
                evs.push(Json::obj(vec![
                    ("name", Json::Str("process_name".to_string())),
                    ("ph", Json::Str("M".to_string())),
                    ("pid", Json::Num(r as f64)),
                    ("tid", Json::Num(0.0)),
                    (
                        "args",
                        Json::obj(vec![(
                            "name",
                            Json::Str(format!("replica{r}")),
                        )]),
                    ),
                ]));
            }
            for ((pid, tid), name) in &self.track_names {
                evs.push(Json::obj(vec![
                    ("name", Json::Str("thread_name".to_string())),
                    ("ph", Json::Str("M".to_string())),
                    ("pid", Json::Num(*pid as f64)),
                    ("tid", Json::Num(*tid as f64)),
                    (
                        "args",
                        Json::obj(vec![(
                            "name",
                            Json::Str(name.clone()),
                        )]),
                    ),
                ]));
            }
            for e in &self.events {
                evs.push(e.to_json());
            }
            Some(
                Json::obj(vec![
                    ("traceEvents", Json::Arr(evs)),
                    (
                        "displayTimeUnit",
                        Json::Str("ms".to_string()),
                    ),
                ])
                .to_string(),
            )
        } else {
            None
        };

        TelemetryOut {
            timeline,
            attribution,
            perfetto,
            incidents: watch_out.map(|wo| wo.summary),
            audit: self.audit,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_is_inert() {
        let mut rec = TraceRecorder::disabled();
        assert!(!rec.is_enabled());
        rec.observe(1.0, 0, &[Action::Complete { req: 0 }]);
        assert!(!rec.sample_due(1e9));
        assert!(rec.finish(10.0).is_none());
    }

    #[test]
    fn flight_recorder_tracks_steps_and_spans() {
        let mut opts = TelemetryOpts::new(SloSpec::default());
        opts.perfetto = true;
        let mut rec = TraceRecorder::flight(opts);
        let reqs = vec![Request::new(0, Class::Online, 0.0, 100, 4)];
        rec.register_requests(&reqs);
        rec.register_replica(0, 2, 2);
        rec.observe(
            0.5,
            0,
            &[Action::StartStep {
                inst: InstanceRef::Relaxed(0),
                kind: StepKind::PrefillOnline,
                participants: vec![0],
                prefill: Vec::new(),
                predicted_latency: 0.2,
                cached_tokens: 0,
                seq: 1,
            }],
        );
        rec.observe(0.7, 0, &[Action::Complete { req: 0 }]);
        let out = rec.finish(1.0).expect("enabled");
        assert_eq!(out.audit.opened_spans, 1);
        // Never closed by a successor: force-closed at end of run.
        assert_eq!(out.audit.force_closed_spans, 1);
        assert_eq!(out.audit.monotone_violations, 0);
        assert_eq!(out.audit.dangling_instance_refs, 0);
        let trace = out.perfetto.expect("perfetto on");
        let parsed = Json::parse(&trace).expect("valid json");
        assert!(matches!(parsed.get("traceEvents"), Json::Arr(_)));
    }

    #[test]
    fn dangling_instance_ref_is_audited() {
        let opts = TelemetryOpts::new(SloSpec::default());
        let mut rec = TraceRecorder::flight(TelemetryOpts {
            perfetto: true,
            ..opts
        });
        rec.register_replica(0, 1, 1);
        rec.observe(
            0.0,
            0,
            &[Action::InstanceDown {
                inst: InstanceRef::Strict(7),
            }],
        );
        let out = rec.finish(1.0).expect("enabled");
        assert!(out.audit.dangling_instance_refs > 0);
    }

    #[test]
    fn chunk_credit_is_reset_on_evict_and_audited() {
        use crate::instance::PrefillSegment;
        let mut rec = TraceRecorder::flight(TelemetryOpts::new(
            SloSpec::default(),
        ));
        let reqs = vec![Request::new(3, Class::Offline, 0.0, 10, 2)];
        rec.register_requests(&reqs);
        rec.register_replica(0, 1, 1);
        let composed = |tokens: usize, last: bool, seq: u64| Action::StartStep {
            inst: InstanceRef::Relaxed(0),
            kind: StepKind::Composed,
            participants: Vec::new(),
            prefill: vec![PrefillSegment { req: 3, tokens, last }],
            predicted_latency: 0.05,
            cached_tokens: 0,
            seq,
        };
        // First attempt: one chunk lands, then the KV is evicted — the
        // discarded chunk must not pollute the recompute's books.
        rec.observe(0.0, 0, &[composed(4, false, 1)]);
        rec.observe(
            0.05,
            0,
            &[Action::Evict {
                inst: InstanceRef::Relaxed(0),
                req: 3,
            }],
        );
        {
            let f = rec.inner.as_ref().expect("flight");
            assert_eq!(f.reqs[3].prefill_credit, 0);
            assert_eq!(f.reqs[3].evictions, 1);
        }
        // Recompute: the prefix cache serves 2 tokens, chunk segments
        // cover the remaining 8.
        rec.observe(0.1, 0, &[composed(5, false, 2)]);
        rec.observe(0.2, 0, &[composed(3, true, 3)]);
        // The measured request agrees: target 10, 2 cached at admission.
        let mut r = reqs[0].clone();
        r.begin_prefill(10, 2);
        r.advance_prefill(8);
        r.mark_first_token(0.25);
        r.generated = r.output_len;
        r.finished_at = Some(0.5);
        rec.finalize_request(&r);
        let f = rec.inner.as_ref().expect("flight");
        assert_eq!(f.audit.chunk_audited, 1);
        assert_eq!(f.audit.chunk_mismatches, 0);
    }

    #[test]
    fn gauge_timeline_flat_log_matches_replay() {
        use crate::config::ServingConfig;
        use crate::coordinator::Policy;
        use crate::sim::{simulate_traced, SimConfig};
        use crate::trace::generator::online_trace;
        use crate::trace::DatasetProfile;

        let trace = online_trace(DatasetProfile::azure_conv(), 1.0, 60.0, 11);
        let mut cfg = SimConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
        cfg.seed = 11;
        let mut opts = TelemetryOpts::new(cfg.serving.slo);
        opts.sample_interval_s = 1.0;
        // `finish` asserts the flat gauge log serializes byte-identically
        // to the per-tick replay; this run just has to sample enough for
        // the assertion to bite on a real timeline.
        let res = simulate_traced(&trace, &cfg, Some(opts));
        let tel = res.telemetry.expect("telemetry armed");
        match tel.timeline {
            Json::Arr(rows) => {
                assert!(!rows.is_empty(), "sampled timeline is empty")
            }
            other => panic!("timeline is not an array: {other:?}"),
        }
    }
}
