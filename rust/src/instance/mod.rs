//! Instance state for the latency-constraint pools (§3.2).
//!
//! Since the elastic pool manager (DESIGN.md §3.6), the pool an instance
//! serves is *runtime state*, not a type: one [`Instance`] struct carries
//! the union of relaxed-role and strict-role state plus its current
//! [`PoolRole`], so the pool manager can drain an instance, flip its role,
//! and warm it into the other pool without reconstructing it.
//!
//! Instances stay passive state containers; the step *decisions* live in
//! `scheduler::SchedulerCore` (over the pure `coordinator` functions) and
//! the time evolution in an `scheduler::Executor` — virtual clock for the
//! simulator, real PJRT execution for the engine. Keeping them dumb means
//! the simulator and the real engine share exactly the same scheduling
//! code paths.

use std::collections::VecDeque;

use crate::kvcache::KvManager;
use crate::prefix::PrefixIndex;
use crate::request::RequestId;

/// Which latency-constraint pool an instance currently serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolRole {
    /// Latency-relaxed: prefill (both classes) + offline decode.
    Relaxed,
    /// Latency-strict: online decode + SLO-bounded offline mix-in.
    Strict,
}

impl PoolRole {
    pub fn name(self) -> &'static str {
        match self {
            PoolRole::Relaxed => "relaxed",
            PoolRole::Strict => "strict",
        }
    }

    /// The pool a repurposed instance moves to.
    pub fn other(self) -> PoolRole {
        match self {
            PoolRole::Relaxed => PoolRole::Strict,
            PoolRole::Strict => PoolRole::Relaxed,
        }
    }
}

impl std::fmt::Display for PoolRole {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// What one iteration (step) on an instance is doing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepKind {
    /// Prefill of online requests (exclusive-step mode,
    /// `chunk_tokens = off`; latency-relaxed pool).
    PrefillOnline,
    /// Prefill of offline requests (exclusive-step mode; relaxed pool).
    PrefillOffline,
    /// Offline decode on a latency-relaxed instance (OOCO's flexibility;
    /// exclusive-step mode).
    DecodeRelaxed,
    /// Mixed decode on a latency-strict instance.
    DecodeStrict,
    /// Chunked-prefill continuous-batching iteration on a relaxed instance
    /// (DESIGN.md §3.8): decode tokens for every resident plus up to the
    /// chunk budget of prefill work from per-request cursors. The step's
    /// real content is its composition (`Step::participants` +
    /// `Step::prefill`), not the kind.
    Composed,
    /// Role-transition warm-up after a pool flip (DESIGN.md §3.6): the
    /// instance re-initializes role-specific runtime state and serves no
    /// requests until the step completes.
    Warm,
}

/// One request's slice of an iteration's prefill work (DESIGN.md §3.8):
/// `tokens` uncached prompt tokens drawn from the request's progress
/// cursor. Part of the differential action stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefillSegment {
    pub req: RequestId,
    /// Uncached prompt tokens this iteration computes for `req`.
    pub tokens: usize,
    /// True when this segment completes the request's prefill (the TTFT
    /// clock stops at this iteration's end).
    pub last: bool,
}

/// A running iteration.
#[derive(Debug, Clone)]
pub struct Step {
    pub kind: StepKind,
    pub started: f64,
    pub ends: f64,
    /// Decode participants (each advances one token), plus — in
    /// exclusive-step mode — the prefill batch of a `Prefill*` step.
    pub participants: Vec<RequestId>,
    /// Prefill chunk segments of a [`StepKind::Composed`] iteration
    /// (empty for exclusive-step and pure-decode iterations).
    pub prefill: Vec<PrefillSegment>,
    /// Monotonic id used to invalidate stale completion events after a
    /// preemption reschedules the step end.
    pub seq: u64,
    /// Preemption latch. Exclusive-step mode: an online arrival truncated
    /// this (offline prefill) step at a layer boundary and its work is
    /// discarded on completion. Composed iterations: an online arrival was
    /// counted against this step's offline chunks (progress is retained by
    /// the cursors — the flag only stops a burst of arrivals from being
    /// counted as multiple preemptions).
    pub preempted: bool,
}

impl Step {
    /// Is `rid` part of this iteration (decode or prefill side)?
    pub fn involves(&self, rid: RequestId) -> bool {
        self.participants.contains(&rid)
            || self.prefill.iter().any(|s| s.req == rid)
    }
}

/// One serving instance. Which fields are active depends on `role`; the
/// inactive role's queues stay empty (asserted by `drained_for_flip`
/// before every role change).
#[derive(Debug)]
pub struct Instance {
    /// Index within the instance's *current* pool (re-assigned on flip).
    pub id: usize,
    pub role: PoolRole,
    /// Set while the pool manager drains this instance for a role flip:
    /// no new work (routing, gating admission, rescue, restore, migration
    /// pull) may target it; resident work finishes or is moved off.
    pub draining: bool,
    /// Crashed (fleet fault model, DESIGN.md §3.9): the instance holds no
    /// KV, runs no steps, and is excluded from every placement decision
    /// until its recovery event flips this back.
    pub down: bool,
    /// Advance crash notice received (spot-instance style): resident
    /// offline KV is being evacuated through the transport engine; the
    /// instance takes no new work but finishes what it holds.
    pub evacuating: bool,
    pub kv: KvManager,
    /// Prefix-sharing block cache over `kv` (DESIGN.md §3.7): maps hashed
    /// token-block chains to physical blocks resident on this instance.
    /// Purged while draining for a role flip.
    pub cache: PrefixIndex,
    // ---- relaxed-role state ----
    /// Online requests waiting to prefill here (router-assigned).
    pub online_queue: VecDeque<RequestId>,
    /// Mid-prefill residents of the chunked iteration model (DESIGN.md
    /// §3.8): admitted, KV partially allocated, progress tracked by the
    /// request's cursor. Admission order is preserved (FIFO resume).
    pub prefilling: Vec<RequestId>,
    /// Offline decode residents (their KV lives here).
    pub offline_decoding: Vec<RequestId>,
    // ---- strict-role state ----
    /// Online decode residents.
    pub online: Vec<RequestId>,
    /// Offline decode residents (mixed in / migrated here).
    pub offline: Vec<RequestId>,
    /// Online requests that could not reserve KV space yet (overload).
    pub waiting_for_space: VecDeque<RequestId>,
    // ---- either role ----
    /// Requests whose KV is streaming *in* (dispatch/migration to a strict
    /// instance; rescue/restore to a relaxed one); space is reserved in
    /// `kv` but they join their resident list only when the transfer lands.
    pub inbound: Vec<RequestId>,
    /// The running iteration. Step seq ids come from the cluster-global
    /// counter (`ClusterState::alloc_seq`) so they stay unique across
    /// elastic role flips.
    pub step: Option<Step>,
    // ---- utilization accounting (retired into `ClusterState` on flip) ----
    pub busy_s: f64,
    pub steps: u64,
    pub offline_decode_tokens: u64,
}

impl Instance {
    pub fn new(
        id: usize,
        role: PoolRole,
        kv_capacity_tokens: usize,
        block_tokens: usize,
    ) -> Self {
        Instance {
            id,
            role,
            draining: false,
            down: false,
            evacuating: false,
            kv: KvManager::new(kv_capacity_tokens, block_tokens),
            cache: PrefixIndex::new(block_tokens),
            online_queue: VecDeque::new(),
            prefilling: Vec::new(),
            offline_decoding: Vec::new(),
            online: Vec::new(),
            offline: Vec::new(),
            waiting_for_space: VecDeque::new(),
            inbound: Vec::new(),
            step: None,
            busy_s: 0.0,
            steps: 0,
            offline_decode_tokens: 0,
        }
    }

    pub fn is_idle(&self) -> bool {
        self.step.is_none()
    }

    /// May new work (admission, rescue/restore, migration pulls, chunked
    /// prefill starts) be placed here? Draining, evacuating, and crashed
    /// instances all refuse.
    pub fn accepts_work(&self) -> bool {
        !self.draining && !self.down && !self.evacuating
    }

    pub fn has_decode_work(&self) -> bool {
        !self.online.is_empty() || !self.offline.is_empty()
    }

    pub fn remove_online(&mut self, id: RequestId) {
        self.online.retain(|&r| r != id);
    }

    pub fn remove_offline(&mut self, id: RequestId) {
        self.offline.retain(|&r| r != id);
    }

    /// No queued, resident, or in-flight work of either role.
    pub fn workload_empty(&self) -> bool {
        self.step.is_none()
            && self.online_queue.is_empty()
            && self.prefilling.is_empty()
            && self.offline_decoding.is_empty()
            && self.online.is_empty()
            && self.offline.is_empty()
            && self.waiting_for_space.is_empty()
            && self.inbound.is_empty()
    }

    /// [`Instance::workload_empty`] and no KV blocks held at all — the
    /// drain phase is complete and the instance may flip to its new pool.
    /// The KV condition matters beyond the queues: a request parked in
    /// another instance's `waiting_for_space` keeps its prefilled KV
    /// *here* without appearing in any local queue, and a flip while those
    /// blocks remain would dangle its `KvHome`. Reclaimable prefix-cache
    /// blocks count too — the core purges a draining instance's cache on
    /// every drain tick, so they never stall a flip in practice.
    pub fn drained_for_flip(&self) -> bool {
        self.workload_empty() && self.kv.used_blocks() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relaxed_lifecycle() {
        let mut r = Instance::new(0, PoolRole::Relaxed, 1000, 16);
        assert!(r.is_idle());
        assert_eq!(r.role, PoolRole::Relaxed);
        assert!(!r.draining);
        r.online_queue.push_back(5);
        assert_eq!(r.online_queue.pop_front(), Some(5));
    }

    #[test]
    fn strict_residency_ops() {
        let mut s = Instance::new(0, PoolRole::Strict, 1000, 16);
        assert!(!s.has_decode_work());
        s.online.extend([1, 2, 3]);
        s.offline.extend([10, 11]);
        assert!(s.has_decode_work());
        s.remove_online(2);
        assert_eq!(s.online, vec![1, 3]);
        s.remove_offline(10);
        assert_eq!(s.offline, vec![11]);
        s.remove_offline(999); // no-op
        assert_eq!(s.offline, vec![11]);
    }

    #[test]
    fn drained_for_flip_tracks_every_queue() {
        let mut i = Instance::new(0, PoolRole::Relaxed, 1000, 16);
        assert!(i.drained_for_flip());
        i.online_queue.push_back(1);
        assert!(!i.drained_for_flip());
        i.online_queue.clear();
        i.inbound.push(2);
        assert!(!i.drained_for_flip());
        i.inbound.clear();
        i.waiting_for_space.push_back(3);
        assert!(!i.drained_for_flip());
        i.waiting_for_space.clear();
        i.prefilling.push(4);
        assert!(!i.drained_for_flip());
        i.prefilling.clear();
        assert!(i.drained_for_flip());
    }

    #[test]
    fn step_involves_both_sides() {
        let step = Step {
            kind: StepKind::Composed,
            started: 0.0,
            ends: 1.0,
            participants: vec![1, 2],
            prefill: vec![PrefillSegment {
                req: 9,
                tokens: 128,
                last: false,
            }],
            seq: 1,
            preempted: false,
        };
        assert!(step.involves(1));
        assert!(step.involves(9));
        assert!(!step.involves(3));
    }

    #[test]
    fn role_other_and_names() {
        assert_eq!(PoolRole::Relaxed.other(), PoolRole::Strict);
        assert_eq!(PoolRole::Strict.other(), PoolRole::Relaxed);
        assert_eq!(PoolRole::Strict.to_string(), "strict");
        assert_eq!(PoolRole::Relaxed.name(), "relaxed");
    }
}
