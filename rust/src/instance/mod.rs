//! Instance state for the two latency-constraint pools (§3.2).
//!
//! These are passive state containers; the step *decisions* live in
//! `scheduler::SchedulerCore` (over the pure `coordinator` functions) and
//! the time evolution in an `scheduler::Executor` — virtual clock for the
//! simulator, real PJRT execution for the engine. Keeping them dumb means
//! the simulator and the real engine share exactly the same scheduling
//! code paths.

use std::collections::VecDeque;

use crate::kvcache::KvManager;
use crate::request::RequestId;

/// What one iteration (step) on an instance is doing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepKind {
    /// Prefill of online requests (latency-relaxed pool).
    PrefillOnline,
    /// Prefill of offline requests (latency-relaxed pool).
    PrefillOffline,
    /// Offline decode on a latency-relaxed instance (OOCO's flexibility).
    DecodeRelaxed,
    /// Mixed decode on a latency-strict instance.
    DecodeStrict,
}

/// A running iteration.
#[derive(Debug, Clone)]
pub struct Step {
    pub kind: StepKind,
    pub started: f64,
    pub ends: f64,
    pub participants: Vec<RequestId>,
    /// Monotonic id used to invalidate stale completion events after a
    /// preemption reschedules the step end.
    pub seq: u64,
    /// Set when an online arrival truncated this (offline prefill) step at
    /// a layer boundary — its work is discarded on completion.
    pub preempted: bool,
}

/// Latency-relaxed instance: prefill (both classes) + offline decode.
#[derive(Debug)]
pub struct RelaxedInstance {
    pub id: usize,
    pub kv: KvManager,
    /// Online requests waiting to prefill here (router-assigned).
    pub online_queue: VecDeque<RequestId>,
    /// Offline decode residents (their KV lives here).
    pub offline_decoding: Vec<RequestId>,
    /// Requests whose KV is streaming *in* (rescue from a strict eviction
    /// or restore from host staging); space is reserved in `kv` but they
    /// join `offline_decoding` only when the transfer lands.
    pub inbound: Vec<RequestId>,
    pub step: Option<Step>,
    pub next_seq: u64,
    // ---- utilization accounting ----
    pub busy_s: f64,
    pub busy_online_prefill_s: f64,
}

impl RelaxedInstance {
    pub fn new(id: usize, kv_capacity_tokens: usize, block_tokens: usize) -> Self {
        RelaxedInstance {
            id,
            kv: KvManager::new(kv_capacity_tokens, block_tokens),
            online_queue: VecDeque::new(),
            offline_decoding: Vec::new(),
            inbound: Vec::new(),
            step: None,
            next_seq: 0,
            busy_s: 0.0,
            busy_online_prefill_s: 0.0,
        }
    }

    pub fn is_idle(&self) -> bool {
        self.step.is_none()
    }

    pub fn alloc_seq(&mut self) -> u64 {
        self.next_seq += 1;
        self.next_seq
    }
}

/// Latency-strict instance: online decode + SLO-bounded offline mix-in.
#[derive(Debug)]
pub struct StrictInstance {
    pub id: usize,
    pub kv: KvManager,
    /// Online decode residents.
    pub online: Vec<RequestId>,
    /// Offline decode residents (mixed in / migrated here).
    pub offline: Vec<RequestId>,
    /// Requests whose KV transfer to this instance is in flight (KV space
    /// already reserved in `kv`).
    pub inbound: Vec<RequestId>,
    /// Online requests that could not reserve KV space yet (overload).
    pub waiting_for_space: VecDeque<RequestId>,
    pub step: Option<Step>,
    pub next_seq: u64,
    // ---- utilization accounting ----
    pub busy_s: f64,
    pub steps: u64,
    pub offline_decode_tokens: u64,
}

impl StrictInstance {
    pub fn new(id: usize, kv_capacity_tokens: usize, block_tokens: usize) -> Self {
        StrictInstance {
            id,
            kv: KvManager::new(kv_capacity_tokens, block_tokens),
            online: Vec::new(),
            offline: Vec::new(),
            inbound: Vec::new(),
            waiting_for_space: VecDeque::new(),
            step: None,
            next_seq: 0,
            busy_s: 0.0,
            steps: 0,
            offline_decode_tokens: 0,
        }
    }

    pub fn is_idle(&self) -> bool {
        self.step.is_none()
    }

    pub fn has_decode_work(&self) -> bool {
        !self.online.is_empty() || !self.offline.is_empty()
    }

    pub fn alloc_seq(&mut self) -> u64 {
        self.next_seq += 1;
        self.next_seq
    }

    pub fn remove_online(&mut self, id: RequestId) {
        self.online.retain(|&r| r != id);
    }

    pub fn remove_offline(&mut self, id: RequestId) {
        self.offline.retain(|&r| r != id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relaxed_lifecycle() {
        let mut r = RelaxedInstance::new(0, 1000, 16);
        assert!(r.is_idle());
        assert_eq!(r.alloc_seq(), 1);
        assert_eq!(r.alloc_seq(), 2);
        r.online_queue.push_back(5);
        assert_eq!(r.online_queue.pop_front(), Some(5));
    }

    #[test]
    fn strict_residency_ops() {
        let mut s = StrictInstance::new(0, 1000, 16);
        assert!(!s.has_decode_work());
        s.online.extend([1, 2, 3]);
        s.offline.extend([10, 11]);
        assert!(s.has_decode_work());
        s.remove_online(2);
        assert_eq!(s.online, vec![1, 3]);
        s.remove_offline(10);
        assert_eq!(s.offline, vec![11]);
        s.remove_offline(999); // no-op
        assert_eq!(s.offline, vec![11]);
    }
}
