//! OOCO: latency-disaggregated architecture for online-offline co-located
//! LLM serving — a three-layer Rust + JAX + Pallas reproduction.
//!
//! Layer 3 (this crate) owns the serving runtime: the latency-constraint
//! disaggregated coordinator (§3), the roofline performance model (§3.3),
//! the discrete-event cluster simulator used for the paper's evaluation
//! sweeps, and the real PJRT engine that executes the AOT artifacts built
//! by `python/compile` (Layers 1–2, build-time only).
//!
//! See DESIGN.md for the module inventory and the per-experiment index.

pub mod config;
pub mod coordinator;
pub mod engine;
pub mod instance;
pub mod kvcache;
pub mod metrics;
pub mod perfmodel;
pub mod request;
pub mod runtime;
pub mod sim;
pub mod sweep;
pub mod testutil;
pub mod trace;
pub mod util;
