//! OOCO: latency-disaggregated architecture for online-offline co-located
//! LLM serving — a three-layer Rust + JAX + Pallas reproduction.
//!
//! Layer 3 (this crate) owns the serving runtime: the latency-constraint
//! disaggregated coordinator (§3), the roofline performance model (§3.3),
//! the unified scheduling subsystem ([`scheduler`]) whose single §3.4
//! decision loop drives both the discrete-event cluster simulator used for
//! the paper's evaluation sweeps and the real PJRT engine that executes the
//! AOT artifacts built by `python/compile` (Layers 1–2, build-time only).
//!
//! See DESIGN.md for the module inventory and the per-experiment index.

pub mod config;
pub mod coordinator;
pub mod engine;
pub mod fleet;
pub mod instance;
pub mod kvcache;
pub mod metrics;
pub mod obs;
pub mod perfmodel;
pub mod pool;
pub mod prefix;
pub mod request;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod sweep;
pub mod telemetry;
pub mod testutil;
pub mod trace;
pub mod transport;
pub mod util;
pub mod watch;

/// One-stop import surface for the public scheduling API.
///
/// ```ignore
/// use ooco::prelude::*;
///
/// let trace = online_trace(DatasetProfile::azure_conv(), 0.5, 600.0, 42);
/// let cfg = SimConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
/// let result = simulate(&trace, &cfg);
/// ```
pub mod prelude {
    pub use crate::config::{
        ChunkMode, ClusterSpec, CrashEvent, FaultPool, FaultSpec,
        FleetSpec, HardwareProfile, LinkSharing, LinkSpec, ModelSpec,
        MtbfSpec, PoolPolicy, PrefixSpec, RoutePolicy, SchedulerParams,
        ServingConfig, SloSpec, TransportSpec,
    };
    pub use crate::coordinator::{Ablation, OverloadMode, Policy};
    pub use crate::engine::{
        serve_trace, serve_trace_with_runtime, EngineConfig, EngineExecutor,
        EngineOutcome,
    };
    pub use crate::fleet::{
        simulate_fleet, simulate_fleet_observed, simulate_fleet_traced,
        Fleet, FleetConfig, FleetResult,
    };
    pub use crate::instance::{PoolRole, PrefillSegment, StepKind};
    pub use crate::metrics::{
        ChunkReport, FleetReport, LinkReport, PoolReport, PrefixReport,
        Recorder, Report, TransportReport,
    };
    pub use crate::obs::{EventClass, ProfileReport, Subsystem};
    pub use crate::perfmodel::{BatchStats, Bottleneck, PerfModel};
    pub use crate::pool::{LoadEstimator, PoolManager, PoolPlan};
    pub use crate::prefix::{PrefixIndex, PrefixMatch};
    pub use crate::request::{Class, Phase, PrefixRef, Request, RequestId};
    pub use crate::scheduler::{
        Action, ClusterState, CoreConfig, ExecStats, Executor, InstanceRef,
        KvHome, RolePhase, SchedulerCore, StubWallClockExecutor,
        VirtualExecutor,
    };
    pub use crate::sim::{
        simulate, simulate_observed, simulate_traced, SimConfig, SimResult,
    };
    pub use crate::telemetry::{
        SpanAudit, TelemetryOpts, TelemetryOut, TraceRecorder,
    };
    pub use crate::transport::{
        ChunkOrder, JobId, TransferJob, TransferKind, TransportEngine,
    };
    pub use crate::trace::{
        datasets::DatasetProfile,
        generator::{offline_trace, online_trace, PromptProfile},
        Trace,
    };
    pub use crate::watch::{
        Incident, IncidentKind, Severity, WatchOut, WatchParams, Watchdog,
    };
}
