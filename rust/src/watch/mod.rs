//! Streaming incident engine (DESIGN.md §3.12).
//!
//! The flight recorder (§3.10) and observatory (§3.11) are passive: they
//! record what happened but nothing *detects* an SLO burn, a
//! prefill/decode-imbalance window, or a saturated link while it is
//! happening. This module rides the same two deterministic taps the
//! recorder already owns — the typed [`Action`] stream and the periodic
//! gauge sampler — and turns them into typed [`Incident`] records:
//!
//! - **multi-window burn-rate SLO alerting** ([`burn::BurnDetector`]) —
//!   SRE-style fast/slow window pairs over rolling TTFT and TPOT
//!   attainment of the online class, with hysteresis so incidents open
//!   and close without flapping;
//! - **a per-replica P/D-imbalance detector** ([`classify::PdDetector`])
//!   — tracks the workload's intrinsic prefill/decode demand ratio
//!   (roofline-model work estimates over the arrival stream) against the
//!   replica's current strict/relaxed split, the paper's core failure
//!   mode surfaced as a first-class signal;
//! - **a Roofline bottleneck classifier**
//!   ([`classify::RooflineClassifier`]) — labels each instance-window
//!   `compute` / `memory_bw` / `transfer` / `queue` (plus `fault` and
//!   `idle`) using [`PerfModel::decode_bottleneck`], mirroring §3's
//!   bottleneck-based scheduling vocabulary; and
//! - **fault incidents** — every `InstanceDown`/`InstanceUp` window
//!   becomes an incident of its own, so crash windows are first-class in
//!   the ledger the fleet smoke asserts on.
//!
//! The ledger lands under the `incidents` key of `--json-out`, as a
//! dedicated `incidents` annotation track in the Perfetto export, and as
//! `ooco_incidents_*` / `ooco_burn_rate` OpenMetrics families. A
//! disabled watchdog is a pure observer: `--watch false` leaves every
//! other output byte-identical (`tests/watch_properties.rs` and CI pin
//! this). Everything derives from the virtual clock and the
//! deterministic action stream — same seed, byte-identical ledger.
//!
//! [`analyze`] re-derives the same ledger offline from any recorded
//! `--json-out` report (`ooco analyze`) and writes a Markdown
//! postmortem with per-incident root causes and remediation hints.

pub mod analyze;
pub mod burn;
pub mod classify;

use std::collections::BTreeMap;

use crate::config::{ServingConfig, SloSpec};
use crate::perfmodel::PerfModel;
use crate::request::{Class, Request};
use crate::scheduler::action::{Action, InstanceRef, RolePhase};
use crate::scheduler::cluster::ClusterState;
use crate::transport::LinkState;
use crate::util::json::Json;

use burn::{BurnDetector, BurnEvent};
use classify::{InstanceGauges, PdDetector, PdEvent, RooflineClassifier};

// ---------------------------------------------------------------- params

/// Tuning of the incident engine. `Copy` so it can ride inside
/// [`crate::telemetry::TelemetryOpts`]; the heavyweight inputs (perf
/// model, serving config) are supplied to [`Watchdog::new`] at wiring
/// time instead.
#[derive(Debug, Clone, Copy)]
pub struct WatchParams {
    /// SLO bounds; `slo.violation_threshold` is the error budget the
    /// burn rates are normalized by.
    pub slo: SloSpec,
    /// Fast ("is it still happening") attainment window, virtual seconds.
    pub fast_window_s: f64,
    /// Slow ("is it significant") attainment window, virtual seconds.
    pub slow_window_s: f64,
    /// Burn-rate threshold on the fast window (multiples of the budget).
    pub fast_burn: f64,
    /// Burn-rate threshold on the slow window.
    pub slow_burn: f64,
    /// Consecutive clear evaluations (fast burn under half its open
    /// threshold) before an open incident closes — the hysteresis band.
    pub clear_ticks: u32,
    /// Completions the slow window must hold before burn rates count;
    /// below this both rates read 0 (no paging on the first request).
    pub min_window_completions: usize,
    /// |log2(intrinsic P:D ratio / provisioned relaxed:strict ratio)|
    /// beyond which a replica counts as imbalanced (1.0 = 2x off).
    pub imbalance_log2: f64,
    /// Consecutive hot evaluations before a P/D-imbalance incident opens.
    pub imbalance_ticks: u32,
    /// Minimum demanded work (model-seconds) in the trailing window for
    /// the imbalance metric to be meaningful.
    pub min_demand_s: f64,
    /// Instance busy fraction above which a window is classified by the
    /// roofline (below it, waiting explanations — transfer/queue — win).
    pub busy_frac_min: f64,
    /// Link utilization above which an under-utilized instance-window
    /// with pending work is `transfer`-bound rather than `queue`-bound.
    pub link_util_min: f64,
}

impl WatchParams {
    pub fn new(slo: SloSpec) -> Self {
        WatchParams {
            slo,
            fast_window_s: 60.0,
            slow_window_s: 240.0,
            fast_burn: 6.0,
            slow_burn: 3.0,
            clear_ticks: 3,
            min_window_completions: 5,
            imbalance_log2: 1.0,
            imbalance_ticks: 3,
            min_demand_s: 1.0,
            busy_frac_min: 0.5,
            link_util_min: 0.5,
        }
    }

    /// The error budget burn rates are expressed in multiples of.
    pub fn budget(&self) -> f64 {
        self.slo.violation_threshold.max(1e-6)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("fast_window_s", Json::Num(self.fast_window_s)),
            ("slow_window_s", Json::Num(self.slow_window_s)),
            ("fast_burn", Json::Num(self.fast_burn)),
            ("slow_burn", Json::Num(self.slow_burn)),
            ("budget", Json::Num(self.budget())),
            ("clear_ticks", Json::Num(self.clear_ticks as f64)),
            ("imbalance_log2", Json::Num(self.imbalance_log2)),
        ])
    }
}

impl Default for WatchParams {
    fn default() -> Self {
        WatchParams::new(SloSpec::default())
    }
}

// -------------------------------------------------------------- incident

/// Incident severity. `Page` means the fast window confirmed the burn at
/// twice its open threshold (or a strict-pool instance went down).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Warn,
    Page,
}

impl Severity {
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Warn => "warn",
            Severity::Page => "page",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IncidentKind {
    /// Multi-window burn-rate SLO violation (fleet-wide, online class).
    SloBurn,
    /// A replica's strict/relaxed split drifted from the workload's
    /// intrinsic prefill/decode demand ratio.
    PdImbalance,
    /// An instance crash window (fleet fault model, §3.9).
    Fault,
}

impl IncidentKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            IncidentKind::SloBurn => "slo_burn",
            IncidentKind::PdImbalance => "pd_imbalance",
            IncidentKind::Fault => "fault",
        }
    }
}

/// One typed incident record — the unit of the `incidents` ledger.
#[derive(Debug, Clone)]
pub struct Incident {
    pub id: u64,
    pub kind: IncidentKind,
    pub severity: Severity,
    /// Affected replica; `None` for fleet-wide (burn) incidents.
    pub replica: Option<usize>,
    /// Affected request class (`"online"` for SLO burns).
    pub class: Option<&'static str>,
    /// Violated metric (`"ttft"` / `"tpot"`) for SLO burns.
    pub metric: Option<&'static str>,
    pub opened_at: f64,
    /// `None` while still open (and for incidents open at end of run).
    pub closed_at: Option<f64>,
    /// Peak detector reading: burn rate (multiples of budget) for SLO
    /// burns, |log2 imbalance| for P/D drift, down-seconds for faults.
    pub peak: f64,
    /// Dominant roofline label over the incident's open window.
    pub bottleneck: String,
    /// Dominant cause, folded in from the §3.10 attribution machinery
    /// for SLO burns (`queueing` / `transfer_stall` / … ), `"fault"`
    /// for crash windows, `"pd_imbalance"` for drift.
    pub cause: String,
    /// Human-readable one-liner.
    pub detail: String,
}

impl Incident {
    pub fn duration_s(&self, end_time: f64) -> f64 {
        (self.closed_at.unwrap_or(end_time) - self.opened_at).max(0.0)
    }

    pub fn to_json(&self, end_time: f64) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("kind", Json::Str(self.kind.as_str().to_string())),
            (
                "severity",
                Json::Str(self.severity.as_str().to_string()),
            ),
            (
                "replica",
                self.replica
                    .map(|r| Json::Num(r as f64))
                    .unwrap_or(Json::Null),
            ),
            (
                "class",
                self.class
                    .map(|c| Json::Str(c.to_string()))
                    .unwrap_or(Json::Null),
            ),
            (
                "metric",
                self.metric
                    .map(|m| Json::Str(m.to_string()))
                    .unwrap_or(Json::Null),
            ),
            ("opened_at", Json::Num(self.opened_at)),
            (
                "closed_at",
                self.closed_at.map(Json::Num).unwrap_or(Json::Null),
            ),
            ("duration_s", Json::Num(self.duration_s(end_time))),
            ("peak", Json::Num(self.peak)),
            ("bottleneck", Json::Str(self.bottleneck.clone())),
            ("cause", Json::Str(self.cause.clone())),
            ("detail", Json::Str(self.detail.clone())),
        ])
    }
}

/// Everything a finished watchdog hands back: the typed records (for the
/// Perfetto annotation track) and the composed `incidents` Json.
#[derive(Debug, Clone)]
pub struct WatchOut {
    pub incidents: Vec<Incident>,
    pub summary: Json,
}

// -------------------------------------------------------------- watchdog

/// Stable per-GPU slot ids per replica, mirrored across pool flips the
/// same way the flight recorder mirrors its Perfetto tracks.
#[derive(Debug, Clone, Default)]
struct SlotMap {
    relaxed: Vec<usize>,
    strict: Vec<usize>,
}

impl SlotMap {
    fn slot(&self, inst: InstanceRef) -> Option<usize> {
        match inst {
            InstanceRef::Relaxed(i) => self.relaxed.get(i).copied(),
            InstanceRef::Strict(i) => self.strict.get(i).copied(),
        }
    }
}

/// One (arrival, relaxed-pool work, strict-pool work) row of the demand
/// ledger the P/D detector integrates over. Work estimates come from the
/// roofline model: prefill (and offline decode) land on the relaxed
/// pool, online decode on the strict pool.
#[derive(Debug, Clone, Copy)]
struct DemandRow {
    arrival: f64,
    relaxed_s: f64,
    strict_s: f64,
}

/// The streaming incident engine. Fed by the flight recorder from the
/// same choke points that build the gauge timeline; owns no wall-clock
/// state, so same-seed ledgers are byte-identical.
#[derive(Debug)]
pub struct Watchdog {
    params: WatchParams,
    pm: PerfModel,
    ttft: BurnDetector,
    tpot: BurnDetector,
    pd: Vec<PdDetector>,
    classify: RooflineClassifier,
    slots: Vec<SlotMap>,
    /// Demand ledger sorted by arrival; `[demand_lo, demand_hi)` is the
    /// trailing slow-window slice currently summed into the running
    /// totals.
    demand: Vec<DemandRow>,
    demand_lo: usize,
    demand_hi: usize,
    relaxed_demand_s: f64,
    strict_demand_s: f64,
    /// Latest sampled (relaxed, strict) pool sizes per replica.
    splits: Vec<(usize, usize)>,
    /// Open incident index per burn metric (0 = ttft, 1 = tpot).
    open_burn: [Option<usize>; 2],
    /// Open incident index per imbalanced replica.
    open_pd: BTreeMap<usize, usize>,
    /// Open fault incident per crashed instance slot.
    open_fault: BTreeMap<(usize, usize), usize>,
    incidents: Vec<Incident>,
    /// `(finish time, dominant cause)` of attributed SLO violations,
    /// folded into overlapping burn incidents at finish.
    attributed: Vec<(f64, &'static str)>,
    last_tick_at: f64,
    ticks: u64,
}

impl Watchdog {
    pub fn new(params: WatchParams, serving: &ServingConfig) -> Self {
        let pm =
            PerfModel::new(serving.model.clone(), serving.hardware.clone());
        Watchdog {
            ttft: BurnDetector::new("ttft"),
            tpot: BurnDetector::new("tpot"),
            pd: Vec::new(),
            classify: RooflineClassifier::new(pm.bs_sat()),
            slots: Vec::new(),
            demand: Vec::new(),
            demand_lo: 0,
            demand_hi: 0,
            relaxed_demand_s: 0.0,
            strict_demand_s: 0.0,
            splits: Vec::new(),
            open_burn: [None, None],
            open_pd: BTreeMap::new(),
            open_fault: BTreeMap::new(),
            incidents: Vec::new(),
            attributed: Vec::new(),
            last_tick_at: 0.0,
            ticks: 0,
            params,
            pm,
        }
    }

    /// Build the demand ledger from the workload statics. Decode
    /// occupancy is priced at the compute-saturated batch size — the
    /// per-token cost an efficiently packed pool would pay.
    pub fn register_requests(&mut self, requests: &[Request]) {
        let bs = self.classify.bs_sat().clamp(1, 1 << 12);
        for r in requests {
            let prefill_s = self.pm.prefill_latency(r.prompt_len);
            let ctx = r.prompt_len + r.output_len / 2;
            let decode_s = r.output_len as f64
                * self.pm.decode_latency(
                    crate::perfmodel::BatchStats::new(bs, bs * ctx),
                )
                / bs as f64;
            let (relaxed_s, strict_s) = if r.class == Class::Online {
                (prefill_s, decode_s)
            } else {
                // Offline work (prefill and decode) is the relaxed
                // pool's responsibility under the paper's split.
                (prefill_s + decode_s, 0.0)
            };
            self.demand.push(DemandRow {
                arrival: r.arrival,
                relaxed_s,
                strict_s,
            });
        }
        self.demand.sort_by(|a, b| {
            a.arrival
                .partial_cmp(&b.arrival)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
    }

    pub fn register_replica(
        &mut self,
        replica: usize,
        relaxed: usize,
        strict: usize,
    ) {
        if self.slots.len() <= replica {
            self.slots.resize(replica + 1, SlotMap::default());
            self.splits.resize(replica + 1, (0, 0));
            while self.pd.len() <= replica {
                self.pd.push(PdDetector::new(self.pd.len()));
            }
        }
        let sm = &mut self.slots[replica];
        sm.relaxed = (0..relaxed).collect();
        sm.strict = (relaxed..relaxed + strict).collect();
        self.splits[replica] = (relaxed, strict);
    }

    // ----------------------------------------------------------- intake

    /// Tap one action batch (same stream the recorder observes).
    pub fn on_actions(&mut self, now: f64, replica: usize, actions: &[Action]) {
        for a in actions {
            match a {
                Action::StartStep {
                    inst,
                    kind,
                    participants,
                    prefill,
                    predicted_latency,
                    ..
                } => {
                    if let Some(slot) =
                        self.slots.get(replica).and_then(|s| s.slot(*inst))
                    {
                        let ptok: usize =
                            prefill.iter().map(|s| s.tokens).sum();
                        self.classify.on_step(
                            replica,
                            slot,
                            *kind,
                            participants.len(),
                            ptok,
                            *predicted_latency,
                        );
                    }
                }
                Action::RoleChange { phase, to, .. } => {
                    if matches!(phase, RolePhase::Flip) {
                        if let Some(sm) = self.slots.get_mut(replica) {
                            // Mirror `ClusterState`: a flip moves the
                            // drained tail instance between pools.
                            match to {
                                crate::instance::PoolRole::Strict => {
                                    if let Some(s) = sm.relaxed.pop() {
                                        sm.strict.push(s);
                                    }
                                }
                                crate::instance::PoolRole::Relaxed => {
                                    if let Some(s) = sm.strict.pop() {
                                        sm.relaxed.push(s);
                                    }
                                }
                            }
                        }
                    }
                }
                Action::InstanceDown { inst } => {
                    self.on_instance_down(now, replica, *inst);
                }
                Action::InstanceUp { inst } => {
                    self.on_instance_up(now, replica, *inst);
                }
                _ => {}
            }
        }
    }

    fn on_instance_down(
        &mut self,
        now: f64,
        replica: usize,
        inst: InstanceRef,
    ) {
        let Some(slot) = self.slots.get(replica).and_then(|s| s.slot(inst))
        else {
            return;
        };
        let (pool, severity) = match inst {
            // Losing strict capacity directly threatens online decode.
            InstanceRef::Strict(_) => ("strict", Severity::Page),
            InstanceRef::Relaxed(_) => ("relaxed", Severity::Warn),
        };
        let id = self.incidents.len();
        self.incidents.push(Incident {
            id: id as u64 + 1,
            kind: IncidentKind::Fault,
            severity,
            replica: Some(replica),
            class: None,
            metric: None,
            opened_at: now,
            closed_at: None,
            peak: 0.0,
            bottleneck: "fault".to_string(),
            cause: "fault".to_string(),
            detail: format!(
                "instance down (replica {replica}, pool {pool}, gpu{slot})"
            ),
        });
        self.open_fault.insert((replica, slot), id);
    }

    fn on_instance_up(&mut self, now: f64, replica: usize, inst: InstanceRef) {
        let Some(slot) = self.slots.get(replica).and_then(|s| s.slot(inst))
        else {
            return;
        };
        if let Some(idx) = self.open_fault.remove(&(replica, slot)) {
            let inc = &mut self.incidents[idx];
            inc.closed_at = Some(now);
            inc.peak = now - inc.opened_at;
        }
    }

    /// Fold one online completion into the burn windows (the recorder
    /// computes the per-metric outcomes from its milestone estimates).
    pub fn on_online_complete(
        &mut self,
        now: f64,
        ttft_ok: bool,
        tpot_ok: bool,
    ) {
        self.ttft.on_complete(now, !ttft_ok);
        self.tpot.on_complete(now, !tpot_ok);
    }

    /// Record one attributed SLO violation (finish time, dominant cause
    /// from the §3.10 decomposition); folded into overlapping burn
    /// incidents at finish.
    pub fn on_attributed(&mut self, finished_at: f64, cause: &'static str) {
        self.attributed.push((finished_at, cause));
    }

    /// Snapshot one replica's gauges (same tick the recorder samples).
    pub fn on_sample(
        &mut self,
        _now: f64,
        replica: usize,
        cluster: &ClusterState,
        links: &[LinkState],
    ) {
        if self.splits.len() <= replica {
            self.register_replica(
                replica,
                cluster.relaxed.len(),
                cluster.strict.len(),
            );
        }
        self.splits[replica] =
            (cluster.relaxed.len(), cluster.strict.len());
        let mut queue = 0usize;
        for inst in cluster.relaxed.iter().chain(cluster.strict.iter()) {
            queue += inst.online_queue.len() + inst.waiting_for_space.len();
        }
        let mut gauges = InstanceGauges {
            replica,
            queue,
            backlog: cluster.offline_backlog.len(),
            link_busy: links.iter().map(|l| l.busy_s).collect(),
            down: Vec::new(),
            kv_used: Vec::new(),
        };
        let sm = &self.slots[replica];
        let n_slots = sm.relaxed.len() + sm.strict.len();
        gauges.down.resize(n_slots, false);
        gauges.kv_used.resize(n_slots, 0);
        for (pool, insts) in
            [(&sm.relaxed, &cluster.relaxed), (&sm.strict, &cluster.strict)]
        {
            for (i, inst) in insts.iter().enumerate() {
                if let Some(&slot) = pool.get(i) {
                    if slot < n_slots {
                        gauges.down[slot] = inst.down;
                        gauges.kv_used[slot] = inst.kv.capacity_tokens()
                            - inst.kv.free_tokens();
                    }
                }
            }
        }
        self.classify.on_sample(gauges);
    }

    // ------------------------------------------------------- evaluation

    /// Advance the demand-window pointers to `now` and return the
    /// trailing-window (relaxed, strict) demanded work.
    fn demand_window(&mut self, now: f64) -> (f64, f64) {
        while self.demand_hi < self.demand.len()
            && self.demand[self.demand_hi].arrival <= now
        {
            let r = self.demand[self.demand_hi];
            self.relaxed_demand_s += r.relaxed_s;
            self.strict_demand_s += r.strict_s;
            self.demand_hi += 1;
        }
        let cutoff = now - self.params.slow_window_s;
        while self.demand_lo < self.demand_hi
            && self.demand[self.demand_lo].arrival < cutoff
        {
            let r = self.demand[self.demand_lo];
            self.relaxed_demand_s -= r.relaxed_s;
            self.strict_demand_s -= r.strict_s;
            self.demand_lo += 1;
        }
        (self.relaxed_demand_s.max(0.0), self.strict_demand_s.max(0.0))
    }

    /// The replica's current imbalance metric:
    /// `log2(intrinsic P:D ratio / provisioned relaxed:strict ratio)`,
    /// `None` when demand is too thin or the split degenerate.
    fn imbalance_metric(
        &self,
        relaxed_demand: f64,
        strict_demand: f64,
        split: (usize, usize),
    ) -> Option<f64> {
        if relaxed_demand + strict_demand < self.params.min_demand_s {
            return None;
        }
        if split.0 == 0 || split.1 == 0 {
            return None;
        }
        if strict_demand <= 1e-9 || relaxed_demand <= 1e-9 {
            return None;
        }
        let intrinsic = relaxed_demand / strict_demand;
        let provisioned = split.0 as f64 / split.1 as f64;
        Some((intrinsic / provisioned).log2())
    }

    /// Evaluate every detector at the gauge tick (after all replicas
    /// sampled). Deterministic order: burn (ttft, tpot), then P/D per
    /// replica ascending.
    pub fn on_tick(&mut self, now: f64) {
        let dt = now - self.last_tick_at;
        self.ticks += 1;
        // Close out the instance-window classifications first so an
        // incident opening on this tick sees the window that opened it.
        if dt > 1e-9 {
            self.classify.tick(now, dt, &self.params);
        }

        for mi in 0..2 {
            let det = if mi == 0 { &mut self.ttft } else { &mut self.tpot };
            match det.tick(now, &self.params) {
                Some(BurnEvent::Opened { at, fast, slow }) => {
                    let metric = if mi == 0 { "ttft" } else { "tpot" };
                    let id = self.incidents.len();
                    self.incidents.push(Incident {
                        id: id as u64 + 1,
                        kind: IncidentKind::SloBurn,
                        severity: Severity::Warn,
                        replica: None,
                        class: Some("online"),
                        metric: Some(metric),
                        opened_at: at,
                        closed_at: None,
                        peak: fast,
                        bottleneck: String::new(),
                        cause: String::new(),
                        detail: format!(
                            "online {metric} burn {fast:.1}x budget \
                             (fast) / {slow:.1}x (slow)"
                        ),
                    });
                    self.open_burn[mi] = Some(id);
                }
                Some(BurnEvent::Closed { at, peak }) => {
                    if let Some(idx) = self.open_burn[mi].take() {
                        let inc = &mut self.incidents[idx];
                        inc.closed_at = Some(at);
                        inc.peak = peak;
                    }
                }
                None => {
                    if let Some(idx) = self.open_burn[mi] {
                        let det =
                            if mi == 0 { &self.ttft } else { &self.tpot };
                        self.incidents[idx].peak = det.peak();
                    }
                }
            }
        }

        let (rd, sd) = self.demand_window(now);
        for replica in 0..self.pd.len() {
            let split = self.splits[replica];
            let metric = self.imbalance_metric(rd, sd, split);
            match self.pd[replica].tick(now, metric, &self.params) {
                Some(PdEvent::Opened { at, metric }) => {
                    let direction = if metric > 0.0 {
                        "prefill-starved (relaxed pool undersized)"
                    } else {
                        "decode-starved (strict pool undersized)"
                    };
                    let id = self.incidents.len();
                    self.incidents.push(Incident {
                        id: id as u64 + 1,
                        kind: IncidentKind::PdImbalance,
                        severity: Severity::Warn,
                        replica: Some(replica),
                        class: None,
                        metric: None,
                        opened_at: at,
                        closed_at: None,
                        peak: metric.abs(),
                        bottleneck: String::new(),
                        cause: "pd_imbalance".to_string(),
                        detail: format!(
                            "replica {replica} {direction}: intrinsic \
                             P:D {:.2}x off the {}r/{}s split",
                            metric.abs().exp2(),
                            split.0,
                            split.1
                        ),
                    });
                    self.open_pd.insert(replica, id);
                }
                Some(PdEvent::Closed { at, peak }) => {
                    if let Some(idx) = self.open_pd.remove(&replica) {
                        let inc = &mut self.incidents[idx];
                        inc.closed_at = Some(at);
                        inc.peak = peak;
                    }
                }
                None => {
                    if let Some(&idx) = self.open_pd.get(&replica) {
                        self.incidents[idx].peak =
                            self.pd[replica].peak();
                    }
                }
            }
        }
        self.last_tick_at = now;
    }

    // ----------------------------------------------------------- finish

    /// Close the books: fold dominant causes and bottleneck labels into
    /// the incidents and compose the `incidents` Json.
    pub fn finish(&mut self, end_time: f64) -> WatchOut {
        // Final partial window so short runs still classify.
        let dt = end_time - self.last_tick_at;
        if dt > 1e-9 {
            self.classify.tick(end_time, dt, &self.params);
        }
        self.attributed.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(b.1))
        });

        for inc in &mut self.incidents {
            let hi = inc.closed_at.unwrap_or(end_time);
            if inc.bottleneck.is_empty() {
                inc.bottleneck = self
                    .classify
                    .dominant_label(inc.replica, inc.opened_at, hi)
                    .to_string();
            }
            if inc.kind == IncidentKind::SloBurn {
                let mut tally: BTreeMap<&'static str, u64> = BTreeMap::new();
                for &(t, cause) in &self.attributed {
                    if t >= inc.opened_at && t <= hi {
                        *tally.entry(cause).or_insert(0) += 1;
                    }
                }
                inc.cause = tally
                    .iter()
                    .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(a.0)))
                    .map(|(c, _)| c.to_string())
                    .unwrap_or_else(|| {
                        classify::cause_of_label(&inc.bottleneck).to_string()
                    });
                if inc.peak >= 2.0 * self.params.fast_burn {
                    inc.severity = Severity::Page;
                }
            }
        }

        let mut by_kind: BTreeMap<&'static str, u64> = BTreeMap::new();
        let mut by_severity: BTreeMap<&'static str, u64> = BTreeMap::new();
        let mut open_at_end = 0u64;
        for inc in &self.incidents {
            *by_kind.entry(inc.kind.as_str()).or_insert(0) += 1;
            *by_severity.entry(inc.severity.as_str()).or_insert(0) += 1;
            if inc.closed_at.is_none() {
                open_at_end += 1;
            }
        }
        let count_map = |m: &BTreeMap<&'static str, u64>| {
            Json::Obj(
                m.iter()
                    .map(|(k, v)| (k.to_string(), Json::Num(*v as f64)))
                    .collect(),
            )
        };
        let burn_json = |d: &BurnDetector| {
            let r = d.rates(end_time, &self.params);
            Json::obj(vec![
                ("fast", Json::Num(r.fast)),
                ("slow", Json::Num(r.slow)),
            ])
        };
        let (rd, sd) = (self.relaxed_demand_s, self.strict_demand_s);
        let pd_rows: Vec<Json> = (0..self.pd.len())
            .map(|replica| {
                let m = self
                    .imbalance_metric(rd, sd, self.splits[replica])
                    .unwrap_or(0.0);
                Json::obj(vec![
                    ("replica", Json::Num(replica as f64)),
                    ("imbalance_log2", Json::Num(m)),
                ])
            })
            .collect();

        let summary = Json::obj(vec![
            (
                "incidents",
                Json::Arr(
                    self.incidents
                        .iter()
                        .map(|i| i.to_json(end_time))
                        .collect(),
                ),
            ),
            ("total", Json::Num(self.incidents.len() as f64)),
            ("open_at_end", Json::Num(open_at_end as f64)),
            ("by_kind", count_map(&by_kind)),
            ("by_severity", count_map(&by_severity)),
            (
                "burn",
                Json::obj(vec![
                    ("ttft", burn_json(&self.ttft)),
                    ("tpot", burn_json(&self.tpot)),
                ]),
            ),
            ("bottleneck_windows", self.classify.counts_json()),
            ("bottleneck_timeline", self.classify.timeline_json()),
            ("pd_imbalance", Json::Arr(pd_rows)),
            ("params", self.params.to_json()),
        ]);

        WatchOut {
            incidents: std::mem::take(&mut self.incidents),
            summary,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_incident_opens_and_closes_with_the_down_window() {
        let serving = ServingConfig::preset_7b();
        let params = WatchParams::new(serving.slo);
        let mut w = Watchdog::new(params, &serving);
        w.register_replica(0, 2, 2);
        w.on_actions(
            10.0,
            0,
            &[Action::InstanceDown {
                inst: InstanceRef::Relaxed(1),
            }],
        );
        w.on_actions(
            40.0,
            0,
            &[Action::InstanceUp {
                inst: InstanceRef::Relaxed(1),
            }],
        );
        let out = w.finish(100.0);
        assert_eq!(out.incidents.len(), 1);
        let inc = &out.incidents[0];
        assert_eq!(inc.kind, IncidentKind::Fault);
        assert_eq!(inc.opened_at, 10.0);
        assert_eq!(inc.closed_at, Some(40.0));
        assert_eq!(inc.cause, "fault");
        assert_eq!(inc.severity, Severity::Warn);
        assert_eq!(out.summary.get("total").as_f64(), Some(1.0));
    }

    #[test]
    fn strict_fault_pages_and_stays_open_without_recovery() {
        let serving = ServingConfig::preset_7b();
        let mut w = Watchdog::new(WatchParams::new(serving.slo), &serving);
        w.register_replica(0, 1, 1);
        w.on_actions(
            5.0,
            0,
            &[Action::InstanceDown {
                inst: InstanceRef::Strict(0),
            }],
        );
        let out = w.finish(50.0);
        assert_eq!(out.incidents[0].severity, Severity::Page);
        assert!(out.incidents[0].closed_at.is_none());
        assert_eq!(out.summary.get("open_at_end").as_f64(), Some(1.0));
    }
}
