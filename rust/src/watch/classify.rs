//! P/D-imbalance detection and Roofline instance-window classification
//! (DESIGN.md §3.12).
//!
//! [`PdDetector`] watches the paper's core failure mode: bursty online
//! traffic shifting the workload's intrinsic prefill/decode demand ratio
//! away from the replica's provisioned strict/relaxed split faster than
//! dynamic adjustment reacts. The watchdog prices demand with the
//! roofline model over a trailing arrival window and hands each detector
//! the drift metric `log2(intrinsic P:D / provisioned R:S)`; the detector
//! is the hysteresis state machine around it.
//!
//! [`RooflineClassifier`] labels every instance-window with the §3
//! bottleneck vocabulary: a busy window is `compute` or `memory_bw`
//! (decode batches classified against the model's compute-saturated batch
//! size `bs_sat`, exactly like [`PerfModel::decode_bottleneck`]); an idle
//! window with pending work is `transfer` when a link ran hot or `queue`
//! otherwise; a down instance is `fault`; everything else is `idle`. The
//! per-tick label grid feeds incident `bottleneck` fields and the
//! `bottleneck_windows` / `bottleneck_timeline` summaries.
//!
//! [`PerfModel::decode_bottleneck`]: crate::perfmodel::PerfModel::decode_bottleneck

use std::collections::BTreeMap;

use crate::instance::StepKind;
use crate::util::json::Json;

use super::WatchParams;

/// Window labels, in tie-break precedence order (earlier wins a tied
/// tally). `idle` never beats a real explanation.
const LABELS: [&str; 6] =
    ["fault", "transfer", "memory_bw", "compute", "queue", "idle"];

fn label_rank(label: &str) -> usize {
    LABELS.iter().position(|l| *l == label).unwrap_or(LABELS.len())
}

/// Map a window label onto the §3.10 attribution cause vocabulary, for
/// incidents with no attributed completions in their window.
pub fn cause_of_label(label: &str) -> &'static str {
    match label {
        "transfer" => "transfer_stall",
        "queue" => "queueing",
        "compute" | "memory_bw" => "compute",
        "fault" => "fault",
        _ => "unknown",
    }
}

// ------------------------------------------------------------ pd drift

/// State transition reported by one [`PdDetector::tick`].
#[derive(Debug, Clone, Copy)]
pub enum PdEvent {
    /// `metric` is the signed log2 drift at open time (positive =
    /// prefill-starved, negative = decode-starved).
    Opened { at: f64, metric: f64 },
    Closed { at: f64, peak: f64 },
}

/// Per-replica hysteresis state machine over the signed imbalance metric.
#[derive(Debug)]
pub struct PdDetector {
    #[allow(dead_code)] // diagnostic tag, useful in Debug output
    replica: usize,
    open: bool,
    hot: u32,
    cool: u32,
    peak: f64,
}

impl PdDetector {
    pub fn new(replica: usize) -> Self {
        PdDetector {
            replica,
            open: false,
            hot: 0,
            cool: 0,
            peak: 0.0,
        }
    }

    /// Peak |log2 drift| observed during the currently open incident.
    pub fn peak(&self) -> f64 {
        self.peak
    }

    /// Evaluate one tick. `metric` is `None` when demand is too thin (or
    /// the split degenerate) to judge — which cools an open incident and
    /// never heats a closed one.
    pub fn tick(
        &mut self,
        now: f64,
        metric: Option<f64>,
        p: &WatchParams,
    ) -> Option<PdEvent> {
        let abs = metric.map(|m| m.abs());
        if !self.open {
            match (metric, abs) {
                (Some(m), Some(a)) if a >= p.imbalance_log2 => {
                    self.hot += 1;
                    if self.hot >= p.imbalance_ticks {
                        self.open = true;
                        self.hot = 0;
                        self.cool = 0;
                        self.peak = a;
                        return Some(PdEvent::Opened { at: now, metric: m });
                    }
                }
                _ => self.hot = 0,
            }
            return None;
        }
        if let Some(a) = abs {
            self.peak = self.peak.max(a);
        }
        let clear = match abs {
            Some(a) => a <= 0.5 * p.imbalance_log2,
            None => true,
        };
        if clear {
            self.cool += 1;
            if self.cool >= p.clear_ticks {
                self.open = false;
                let peak = self.peak;
                self.cool = 0;
                return Some(PdEvent::Closed { at: now, peak });
            }
        } else {
            self.cool = 0;
        }
        None
    }
}

// ------------------------------------------------------ roofline labels

/// One replica's gauge snapshot handed to [`RooflineClassifier::on_sample`]
/// (indexed by the watchdog's stable per-GPU slots).
#[derive(Debug, Clone)]
pub struct InstanceGauges {
    pub replica: usize,
    /// Pending online work across the replica's pools (queues + waiting
    /// for KV space).
    pub queue: usize,
    /// Offline backlog depth.
    pub backlog: usize,
    /// Cumulative per-link busy seconds (utilization comes from the
    /// tick-over-tick delta).
    pub link_busy: Vec<f64>,
    pub down: Vec<bool>,
    pub kv_used: Vec<usize>,
}

/// Step work accumulated on one GPU slot since the last tick.
#[derive(Debug, Clone, Copy, Default)]
struct SlotAccum {
    prefill_s: f64,
    decode_s: f64,
    /// `decode_s`-weighted participant count (mean batch size =
    /// `batch_weight / decode_s`).
    batch_weight: f64,
}

#[derive(Debug, Default)]
struct ReplicaState {
    gauges: Option<InstanceGauges>,
    link_prev: Vec<f64>,
    slots: Vec<SlotAccum>,
}

/// One tick's labels for one replica.
#[derive(Debug, Clone)]
struct TickRow {
    t: f64,
    replica: usize,
    dominant: &'static str,
    labels: Vec<&'static str>,
}

/// Labels instance-windows with the roofline bottleneck vocabulary.
#[derive(Debug)]
pub struct RooflineClassifier {
    bs_sat: usize,
    replicas: Vec<ReplicaState>,
    counts: BTreeMap<&'static str, u64>,
    timeline: Vec<TickRow>,
}

impl RooflineClassifier {
    pub fn new(bs_sat: usize) -> Self {
        RooflineClassifier {
            bs_sat,
            replicas: Vec::new(),
            counts: BTreeMap::new(),
            timeline: Vec::new(),
        }
    }

    pub fn bs_sat(&self) -> usize {
        self.bs_sat
    }

    fn replica_mut(&mut self, replica: usize) -> &mut ReplicaState {
        if self.replicas.len() <= replica {
            self.replicas
                .resize_with(replica + 1, ReplicaState::default);
        }
        &mut self.replicas[replica]
    }

    /// Fold one started step into its slot's window accumulators. A
    /// [`StepKind::Composed`] iteration splits by computed tokens:
    /// `prefill_tokens` chunk tokens vs one decode token per participant.
    pub fn on_step(
        &mut self,
        replica: usize,
        slot: usize,
        kind: StepKind,
        participants: usize,
        prefill_tokens: usize,
        dur: f64,
    ) {
        let rs = self.replica_mut(replica);
        if rs.slots.len() <= slot {
            rs.slots.resize(slot + 1, SlotAccum::default());
        }
        let acc = &mut rs.slots[slot];
        match kind {
            StepKind::PrefillOnline | StepKind::PrefillOffline
            | StepKind::Warm => acc.prefill_s += dur,
            StepKind::DecodeRelaxed | StepKind::DecodeStrict => {
                acc.decode_s += dur;
                acc.batch_weight += dur * participants as f64;
            }
            StepKind::Composed => {
                let total = (prefill_tokens + participants) as f64;
                let pfrac = if total > 0.0 {
                    prefill_tokens as f64 / total
                } else {
                    0.0
                };
                acc.prefill_s += dur * pfrac;
                let d = dur * (1.0 - pfrac);
                acc.decode_s += d;
                acc.batch_weight += d * participants as f64;
            }
        }
    }

    /// Store the latest gauge snapshot (one per replica per tick).
    pub fn on_sample(&mut self, gauges: InstanceGauges) {
        let replica = gauges.replica;
        self.replica_mut(replica).gauges = Some(gauges);
    }

    /// Close the `(now - dt, now]` window: label every slot, append the
    /// per-replica rows, reset the accumulators.
    pub fn tick(&mut self, now: f64, dt: f64, p: &WatchParams) {
        let bs_sat = self.bs_sat;
        for r in 0..self.replicas.len() {
            let rs = &mut self.replicas[r];
            let Some(g) = rs.gauges.as_ref() else {
                for acc in rs.slots.iter_mut() {
                    *acc = SlotAccum::default();
                }
                continue;
            };
            let link_util = g
                .link_busy
                .iter()
                .zip(rs.link_prev.iter().chain(std::iter::repeat(&0.0)))
                .map(|(now_b, prev_b)| ((now_b - prev_b) / dt).max(0.0))
                .fold(0.0f64, f64::max);
            let pending = g.queue > 0 || g.backlog > 0;
            let n_slots = rs.slots.len().max(g.down.len());
            let mut labels: Vec<&'static str> = Vec::with_capacity(n_slots);
            for slot in 0..n_slots {
                let acc = rs.slots.get(slot).copied().unwrap_or_default();
                let down = g.down.get(slot).copied().unwrap_or(false);
                let busy =
                    ((acc.prefill_s + acc.decode_s) / dt).clamp(0.0, 1.0);
                let label = if down {
                    "fault"
                } else if busy >= p.busy_frac_min {
                    if acc.prefill_s >= acc.decode_s {
                        // Prefill-dominated windows are GEMM-bound by
                        // construction (paper §3.3.3).
                        "compute"
                    } else {
                        let mean_batch = if acc.decode_s > 1e-12 {
                            acc.batch_weight / acc.decode_s
                        } else {
                            0.0
                        };
                        // Same branch as PerfModel::decode_bottleneck.
                        if mean_batch >= bs_sat as f64 {
                            "compute"
                        } else {
                            "memory_bw"
                        }
                    }
                } else if pending {
                    if link_util >= p.link_util_min {
                        "transfer"
                    } else {
                        "queue"
                    }
                } else {
                    "idle"
                };
                labels.push(label);
                *self.counts.entry(label).or_insert(0) += 1;
            }
            rs.link_prev = g.link_busy.clone();
            for acc in rs.slots.iter_mut() {
                *acc = SlotAccum::default();
            }
            let dominant = dominant_of(labels.iter().copied());
            self.timeline.push(TickRow {
                t: now,
                replica: r,
                dominant,
                labels,
            });
        }
    }

    /// Dominant label over `[lo, hi]`, optionally restricted to one
    /// replica: tally of per-slot labels, `idle` only when nothing else
    /// appears, ties broken by [`LABELS`] precedence.
    pub fn dominant_label(
        &self,
        replica: Option<usize>,
        lo: f64,
        hi: f64,
    ) -> &'static str {
        let mut tally: BTreeMap<&'static str, u64> = BTreeMap::new();
        for row in &self.timeline {
            if row.t < lo || row.t > hi {
                continue;
            }
            if let Some(r) = replica {
                if row.replica != r {
                    continue;
                }
            }
            for l in &row.labels {
                *tally.entry(l).or_insert(0) += 1;
            }
        }
        if tally.is_empty() {
            return "unknown";
        }
        dominant_of_tally(&tally)
    }

    pub fn counts_json(&self) -> Json {
        Json::Obj(
            self.counts
                .iter()
                .map(|(k, v)| (k.to_string(), Json::Num(*v as f64)))
                .collect(),
        )
    }

    pub fn timeline_json(&self) -> Json {
        Json::Arr(
            self.timeline
                .iter()
                .map(|row| {
                    Json::obj(vec![
                        ("t", Json::Num(row.t)),
                        ("replica", Json::Num(row.replica as f64)),
                        ("label", Json::Str(row.dominant.to_string())),
                        (
                            "labels",
                            Json::Arr(
                                row.labels
                                    .iter()
                                    .map(|l| Json::Str(l.to_string()))
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        )
    }
}

fn dominant_of(labels: impl Iterator<Item = &'static str>) -> &'static str {
    let mut tally: BTreeMap<&'static str, u64> = BTreeMap::new();
    for l in labels {
        *tally.entry(l).or_insert(0) += 1;
    }
    if tally.is_empty() {
        return "idle";
    }
    dominant_of_tally(&tally)
}

fn dominant_of_tally(tally: &BTreeMap<&'static str, u64>) -> &'static str {
    tally
        .iter()
        .filter(|(l, _)| **l != "idle")
        .max_by(|a, b| {
            a.1.cmp(b.1)
                .then_with(|| label_rank(b.0).cmp(&label_rank(a.0)))
        })
        .map(|(l, _)| *l)
        .unwrap_or("idle")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SloSpec;

    fn params() -> WatchParams {
        WatchParams::new(SloSpec::default())
    }

    #[test]
    fn pd_detector_needs_sustained_drift_and_clears_with_hysteresis() {
        let p = params();
        let mut d = PdDetector::new(0);
        // One hot tick is not enough.
        assert!(d.tick(5.0, Some(1.5), &p).is_none());
        assert!(d.tick(10.0, Some(1.5), &p).is_none());
        let ev = d.tick(15.0, Some(1.6), &p);
        assert!(matches!(ev, Some(PdEvent::Opened { metric, .. })
            if metric > 0.0));
        // Band readings (between half and full threshold) keep it open.
        for t in [20.0, 25.0, 30.0] {
            assert!(d.tick(t, Some(0.8), &p).is_none());
        }
        // Sustained clear readings close it; peak survived.
        assert!(d.tick(35.0, Some(0.1), &p).is_none());
        assert!(d.tick(40.0, Some(0.1), &p).is_none());
        let ev = d.tick(45.0, Some(0.1), &p);
        assert!(matches!(ev, Some(PdEvent::Closed { peak, .. })
            if (peak - 1.6).abs() < 1e-9));
    }

    #[test]
    fn pd_detector_interrupted_heat_resets() {
        let p = params();
        let mut d = PdDetector::new(0);
        assert!(d.tick(5.0, Some(2.0), &p).is_none());
        assert!(d.tick(10.0, Some(0.2), &p).is_none()); // resets hot count
        assert!(d.tick(15.0, Some(2.0), &p).is_none());
        assert!(d.tick(20.0, Some(2.0), &p).is_none());
        assert!(matches!(
            d.tick(25.0, Some(2.0), &p),
            Some(PdEvent::Opened { .. })
        ));
    }

    fn gauges(
        replica: usize,
        queue: usize,
        link_busy: Vec<f64>,
        down: Vec<bool>,
    ) -> InstanceGauges {
        let n = down.len();
        InstanceGauges {
            replica,
            queue,
            backlog: 0,
            link_busy,
            down,
            kv_used: vec![0; n],
        }
    }

    #[test]
    fn busy_windows_classify_by_batch_size_against_bs_sat() {
        let p = params();
        let mut c = RooflineClassifier::new(64);
        // Slot 0: decode at mean batch 128 (>= bs_sat) → compute.
        c.on_step(0, 0, StepKind::DecodeStrict, 128, 0, 4.0);
        // Slot 1: decode at mean batch 8 (< bs_sat) → memory_bw.
        c.on_step(0, 1, StepKind::DecodeStrict, 8, 0, 4.0);
        c.on_sample(gauges(0, 0, vec![], vec![false, false]));
        c.tick(5.0, 5.0, &p);
        // 1:1 tie between the two busy labels → precedence order wins.
        assert_eq!(c.dominant_label(Some(0), 0.0, 5.0), "memory_bw");
        let counts = c.counts_json();
        assert_eq!(counts.get("compute").as_f64(), Some(1.0));
        assert_eq!(counts.get("memory_bw").as_f64(), Some(1.0));
    }

    #[test]
    fn idle_with_pending_work_is_transfer_or_queue_by_link_util() {
        let p = params();
        let mut c = RooflineClassifier::new(64);
        // Tick 1: idle slots, deep queue, links cold → queue.
        c.on_sample(gauges(0, 10, vec![0.0], vec![false]));
        c.tick(5.0, 5.0, &p);
        assert_eq!(c.dominant_label(Some(0), 0.0, 5.0), "queue");
        // Tick 2: links ran hot (4 busy-seconds over a 5s window) →
        // transfer-bound.
        c.on_sample(gauges(0, 10, vec![4.0], vec![false]));
        c.tick(10.0, 5.0, &p);
        assert_eq!(c.dominant_label(Some(0), 6.0, 10.0), "transfer");
        // Down instance wins over everything.
        c.on_sample(gauges(0, 10, vec![4.0], vec![true]));
        c.tick(15.0, 5.0, &p);
        assert_eq!(c.dominant_label(Some(0), 11.0, 15.0), "fault");
    }

    #[test]
    fn dominant_label_ignores_idle_unless_alone() {
        let p = params();
        let mut c = RooflineClassifier::new(64);
        c.on_step(0, 0, StepKind::PrefillOnline, 1, 0, 5.0);
        c.on_sample(gauges(0, 0, vec![], vec![false, false, false]));
        c.tick(5.0, 5.0, &p);
        // Two idle slots vs one compute slot: compute still dominates.
        assert_eq!(c.dominant_label(Some(0), 0.0, 5.0), "compute");
        assert_eq!(c.dominant_label(Some(0), 100.0, 200.0), "unknown");
    }
}
