//! Offline postmortems — the `ooco analyze` subcommand (DESIGN.md §3.12).
//!
//! Post-processes any recorded `--json-out` report into the incident
//! ledger plus a human-readable Markdown postmortem (timeline, top
//! incidents, per-incident root cause, remediation hint keyed to the
//! detected bottleneck).
//!
//! When the report was recorded with the watchdog armed it already
//! carries the streaming engine's ledger under `incidents` —
//! [`ledger_from_report`] passes that through verbatim, so online and
//! offline analysis agree byte-for-byte. Reports recorded without the
//! watchdog are re-derived from the gauge `timeline` at sample
//! granularity: crash windows come from the `down` gauge and SLO burns
//! from the rolling `slo_attainment` gauge, with the same thresholds and
//! hysteresis, but bottleneck labels are limited to what gauges can see
//! (`fault` / `transfer` / `queue` / `idle` — no per-step roofline
//! split) and the ledger says so via `"derived": true`.

use crate::util::json::Json;

use super::WatchParams;

/// Extract (or re-derive) the incident ledger from a recorded report.
pub fn ledger_from_report(report: &Json) -> Json {
    let inc = report.get("incidents");
    if inc.as_obj().is_some() {
        return inc.clone();
    }
    derive_ledger(report)
}

/// One gauge tick folded across replicas.
struct Tick {
    t: f64,
    down: f64,
    queue: f64,
    link_util: f64,
    attainment: Option<f64>,
}

/// Per-replica down-gauge row.
struct DownRow {
    t: f64,
    replica: usize,
    down: f64,
}

/// Re-derive a (coarser) ledger from the gauge timeline alone.
fn derive_ledger(report: &Json) -> Json {
    let p = WatchParams::default();
    let rows = report.get("timeline").as_arr().unwrap_or(&[]);

    // Fold per-replica samples into per-tick fleet aggregates (samples at
    // the same `t` belong to one sampler tick).
    let mut ticks: Vec<Tick> = Vec::new();
    let mut down_rows: Vec<DownRow> = Vec::new();
    for row in rows {
        let t = row.get("t").as_f64().unwrap_or(0.0);
        let replica = row.get("replica").as_f64().unwrap_or(0.0) as usize;
        let down = row.get("down").as_f64().unwrap_or(0.0);
        let queue = row.get("online_queue").as_f64().unwrap_or(0.0)
            + row.get("offline_backlog").as_f64().unwrap_or(0.0);
        let link_util = row
            .get("link_utilization")
            .as_arr()
            .map(|ls| {
                ls.iter()
                    .filter_map(|l| l.as_f64())
                    .fold(0.0f64, f64::max)
            })
            .unwrap_or(0.0);
        let att = row.get("slo_attainment").as_f64();
        down_rows.push(DownRow { t, replica, down });
        match ticks.last_mut() {
            Some(last) if (last.t - t).abs() < 1e-9 => {
                last.down += down;
                last.queue += queue;
                last.link_util = last.link_util.max(link_util);
                last.attainment = att; // fleet-wide gauge, keep latest
            }
            _ => ticks.push(Tick {
                t,
                down,
                queue,
                link_util,
                attainment: att,
            }),
        }
    }

    let end_time = ticks.last().map(|s| s.t).unwrap_or(0.0);
    let mut incidents: Vec<Json> = Vec::new();
    let mut next_id = 1u64;
    let mut push = |id: &mut u64,
                    kind: &str,
                    severity: &str,
                    replica: Option<usize>,
                    opened: f64,
                    closed: Option<f64>,
                    peak: f64,
                    bottleneck: &str,
                    cause: &str,
                    detail: String|
     -> Json {
        let j = Json::obj(vec![
            ("id", Json::Num(*id as f64)),
            ("kind", Json::Str(kind.to_string())),
            ("severity", Json::Str(severity.to_string())),
            (
                "replica",
                replica.map(|r| Json::Num(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "class",
                if kind == "slo_burn" {
                    Json::Str("online".to_string())
                } else {
                    Json::Null
                },
            ),
            ("metric", Json::Null),
            ("opened_at", Json::Num(opened)),
            ("closed_at", closed.map(Json::Num).unwrap_or(Json::Null)),
            (
                "duration_s",
                Json::Num((closed.unwrap_or(end_time) - opened).max(0.0)),
            ),
            ("peak", Json::Num(peak)),
            ("bottleneck", Json::Str(bottleneck.to_string())),
            ("cause", Json::Str(cause.to_string())),
            ("detail", Json::Str(detail)),
        ]);
        *id += 1;
        j
    };

    // Fault incidents: contiguous down>0 windows per replica.
    down_rows.sort_by(|a, b| {
        a.replica.cmp(&b.replica).then(
            a.t.partial_cmp(&b.t).unwrap_or(std::cmp::Ordering::Equal),
        )
    });
    let mut open: Option<(usize, f64, f64)> = None; // (replica, since, peak)
    let mut i = 0;
    while i <= down_rows.len() {
        let cur = down_rows.get(i);
        match (&mut open, cur) {
            (None, Some(r)) if r.down > 0.0 => {
                open = Some((r.replica, r.t, r.down));
            }
            (Some((rep, since, peak)), cur) => {
                let closes = match cur {
                    Some(r) if r.replica == *rep => {
                        if r.down > 0.0 {
                            *peak = peak.max(r.down);
                            false
                        } else {
                            true
                        }
                    }
                    _ => true, // replica changed or rows exhausted
                };
                if closes {
                    let closed = cur
                        .filter(|r| r.replica == *rep)
                        .map(|r| r.t);
                    let (rep, since, peak) = (*rep, *since, *peak);
                    incidents.push(push(
                        &mut next_id,
                        "fault",
                        "warn",
                        Some(rep),
                        since,
                        closed,
                        peak,
                        "fault",
                        "fault",
                        format!(
                            "derived from `down` gauge (replica {rep})"
                        ),
                    ));
                    open = None;
                    // Re-examine the current row: it may itself start a
                    // window on the next replica.
                    continue;
                }
            }
            _ => {}
        }
        i += 1;
    }

    // SLO-burn incidents from the rolling attainment gauge: the gauge is
    // already a trailing fast-window violation fraction, so burn = frac /
    // budget; the slow reading is its trailing mean. Hysteresis matches
    // the streaming detector.
    let budget = p.budget();
    let mut burn_open: Option<(f64, f64)> = None; // (since, peak)
    let mut cool = 0u32;
    for (ti, s) in ticks.iter().enumerate() {
        let Some(att) = s.attainment else { continue };
        let fast = (1.0 - att) / budget;
        let slow_cut = s.t - p.slow_window_s;
        let (mut sum, mut n) = (0.0, 0usize);
        for prev in ticks[..=ti].iter().rev() {
            if prev.t < slow_cut {
                break;
            }
            if let Some(a) = prev.attainment {
                sum += (1.0 - a) / budget;
                n += 1;
            }
        }
        let slow = if n > 0 { sum / n as f64 } else { 0.0 };
        match &mut burn_open {
            None => {
                if fast >= p.fast_burn && slow >= p.slow_burn {
                    burn_open = Some((s.t, fast));
                    cool = 0;
                }
            }
            Some((since, peak)) => {
                *peak = peak.max(fast);
                if fast <= 0.5 * p.fast_burn {
                    cool += 1;
                    if cool >= p.clear_ticks {
                        let (since, peak) = (*since, *peak);
                        let label = burn_label(&ticks, since, s.t, &p);
                        let sev = if peak >= 2.0 * p.fast_burn {
                            "page"
                        } else {
                            "warn"
                        };
                        incidents.push(push(
                            &mut next_id,
                            "slo_burn",
                            sev,
                            None,
                            since,
                            Some(s.t),
                            peak,
                            label,
                            super::classify::cause_of_label(label),
                            "derived from `slo_attainment` gauge"
                                .to_string(),
                        ));
                        burn_open = None;
                    }
                } else {
                    cool = 0;
                }
            }
        }
    }
    if let Some((since, peak)) = burn_open {
        let label = burn_label(&ticks, since, end_time, &p);
        let sev = if peak >= 2.0 * p.fast_burn { "page" } else { "warn" };
        incidents.push(push(
            &mut next_id,
            "slo_burn",
            sev,
            None,
            since,
            None,
            peak,
            label,
            super::classify::cause_of_label(label),
            "derived from `slo_attainment` gauge".to_string(),
        ));
    }

    incidents.sort_by(|a, b| {
        a.get("opened_at")
            .as_f64()
            .partial_cmp(&b.get("opened_at").as_f64())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    for (i, inc) in incidents.iter_mut().enumerate() {
        inc.set("id", Json::Num(i as f64 + 1.0));
    }

    let mut by_kind: std::collections::BTreeMap<String, u64> =
        Default::default();
    let mut open_at_end = 0u64;
    for inc in &incidents {
        if let Some(k) = inc.get("kind").as_str() {
            *by_kind.entry(k.to_string()).or_insert(0) += 1;
        }
        if inc.get("closed_at").as_f64().is_none() {
            open_at_end += 1;
        }
    }

    Json::obj(vec![
        ("derived", Json::Bool(true)),
        ("total", Json::Num(incidents.len() as f64)),
        ("open_at_end", Json::Num(open_at_end as f64)),
        (
            "by_kind",
            Json::Obj(
                by_kind
                    .into_iter()
                    .map(|(k, v)| (k, Json::Num(v as f64)))
                    .collect(),
            ),
        ),
        ("incidents", Json::Arr(incidents)),
    ])
}

/// Coarse bottleneck label for a derived burn window: what the fleet
/// gauges can see (`fault` / `transfer` / `queue` / `idle`).
fn burn_label(ticks: &[Tick], lo: f64, hi: f64, p: &WatchParams) -> &'static str {
    let (mut fault, mut transfer, mut queue, mut idle) = (0u64, 0u64, 0u64, 0u64);
    for s in ticks {
        if s.t < lo || s.t > hi {
            continue;
        }
        if s.down > 0.0 {
            fault += 1;
        } else if s.queue > 0.0 && s.link_util >= p.link_util_min {
            transfer += 1;
        } else if s.queue > 0.0 {
            queue += 1;
        } else {
            idle += 1;
        }
    }
    [
        ("fault", fault),
        ("transfer", transfer),
        ("queue", queue),
        ("idle", idle),
    ]
    .iter()
    .max_by_key(|(_, n)| *n)
    .filter(|(_, n)| *n > 0)
    .map(|(l, _)| *l)
    .unwrap_or("unknown")
}

// -------------------------------------------------------------- markdown

/// Remediation hint keyed to the incident's detected bottleneck — the
/// paper's own levers, phrased as operator actions.
pub fn remediation(bottleneck: &str, cause: &str) -> &'static str {
    match (bottleneck, cause) {
        (_, "pd_imbalance") | ("pd", _) => {
            "re-plan the strict/relaxed split (or lower the elastic \
             planner's reaction window) so provisioned capacity tracks \
             the intrinsic prefill/decode demand ratio"
        }
        ("fault", _) | (_, "fault") => {
            "provision N+1 per pool and widen the fault notice window so \
             KV evacuates (restreams) instead of recomputing"
        }
        ("transfer", _) | (_, "transfer_stall") => {
            "add link bandwidth or raise the transfer chunk size; check \
             that migrations are not fighting evacuations for the same \
             links"
        }
        ("memory_bw", _) => {
            "decode batches are below the compute-saturation point: grow \
             per-instance batch (more KV capacity, prefix cache) or \
             mix in offline decodes to fill the memory-bandwidth window"
        }
        ("compute", _) | (_, "chunk_interference") => {
            "compute-saturated: lower the chunk-prefill budget to protect \
             TPOT, or add relaxed instances to absorb the prefill wave"
        }
        ("queue", _) | (_, "queueing") => {
            "arrival rate exceeds serving capacity: add replicas, enable \
             work stealing, or shed offline admission under overload"
        }
        _ => {
            "inspect the Perfetto trace around the incident window \
             (`--trace-out`) — the gauges did not name a single culprit"
        }
    }
}

fn fmt_num(v: Option<f64>) -> String {
    match v {
        Some(x) if x == x.trunc() && x.abs() < 1e12 => {
            format!("{}", x as i64)
        }
        Some(x) => format!("{x:.3}"),
        None => "—".to_string(),
    }
}

fn fmt_opt_str(j: &Json) -> String {
    j.as_str()
        .map(|s| s.to_string())
        .unwrap_or_else(|| "—".to_string())
}

/// Render the Markdown postmortem for a report + its incident ledger.
pub fn postmortem_md(report: &Json, ledger: &Json) -> String {
    let mut md = String::new();
    let meta = report.get("meta");
    let seed = report.get("seed").as_f64().or(meta.get("seed").as_f64());
    let cfg_hash = meta
        .get("config_hash")
        .as_str()
        .unwrap_or("unknown")
        .to_string();
    md.push_str(&format!(
        "# OOCO postmortem — seed {}, config `{}`\n\n",
        fmt_num(seed),
        cfg_hash
    ));
    md.push_str(
        "Generated by `ooco analyze` from a recorded `--json-out` \
         report.\n\n",
    );
    if ledger.get("derived").as_bool() == Some(true) {
        md.push_str(
            "> **Note:** this report carried no streaming `incidents` \
             ledger; incidents below were re-derived from the gauge \
             timeline at sample granularity (bottleneck labels limited \
             to what gauges can see).\n\n",
        );
    }

    md.push_str("## Run summary\n\n");
    md.push_str("| metric | value |\n|---|---|\n");
    let rep = report.get("report");
    for (label, path) in [
        ("duration (s)", "duration_s"),
        ("online finished", "online_finished"),
        ("online SLO attainment", "slo_attainment"),
        ("online violations", "online_violations"),
        ("offline finished", "offline_finished"),
        ("offline tok/s", "offline_token_throughput"),
    ] {
        if let Some(v) = rep.get(path).as_f64() {
            md.push_str(&format!("| {label} | {} |\n", fmt_num(Some(v))));
        }
    }
    for (label, path) in
        [("TTFT p99 (s)", "ttft"), ("TPOT p99 (s)", "tpot")]
    {
        if let Some(v) = rep.get(path).get("p99").as_f64() {
            md.push_str(&format!("| {label} | {v:.3} |\n"));
        }
    }
    if let Some(f) = report.get("fleet").as_obj() {
        for key in ["replicas", "crashes", "availability"] {
            if let Some(v) =
                f.get(key).and_then(|j| j.as_f64())
            {
                md.push_str(&format!(
                    "| fleet {key} | {} |\n",
                    fmt_num(Some(v))
                ));
            }
        }
    }
    md.push('\n');

    let incidents = ledger.get("incidents").as_arr().unwrap_or(&[]);
    md.push_str(&format!(
        "## Incident timeline ({} total, {} open at end)\n\n",
        fmt_num(ledger.get("total").as_f64()),
        fmt_num(ledger.get("open_at_end").as_f64()),
    ));
    if incidents.is_empty() {
        md.push_str("No incidents. Quiet run.\n");
        return md;
    }
    md.push_str(
        "| # | opened | closed | kind | sev | replica | bottleneck | \
         cause | peak |\n|---|---|---|---|---|---|---|---|---|\n",
    );
    let mut ordered: Vec<&Json> = incidents.iter().collect();
    ordered.sort_by(|a, b| {
        a.get("opened_at")
            .as_f64()
            .partial_cmp(&b.get("opened_at").as_f64())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    for inc in &ordered {
        md.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {} | {} | {:.1} |\n",
            fmt_num(inc.get("id").as_f64()),
            fmt_num(inc.get("opened_at").as_f64()),
            fmt_num(inc.get("closed_at").as_f64()),
            fmt_opt_str(inc.get("kind")),
            fmt_opt_str(inc.get("severity")),
            fmt_num(inc.get("replica").as_f64()),
            fmt_opt_str(inc.get("bottleneck")),
            fmt_opt_str(inc.get("cause")),
            inc.get("peak").as_f64().unwrap_or(0.0),
        ));
    }
    md.push('\n');

    // Top incidents: longest first, cap at 5 write-ups.
    let mut top: Vec<&Json> = incidents.iter().collect();
    top.sort_by(|a, b| {
        b.get("duration_s")
            .as_f64()
            .partial_cmp(&a.get("duration_s").as_f64())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| {
                a.get("id")
                    .as_f64()
                    .partial_cmp(&b.get("id").as_f64())
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
    });
    md.push_str("## Top incidents\n\n");
    for inc in top.iter().take(5) {
        let bottleneck = fmt_opt_str(inc.get("bottleneck"));
        let cause = fmt_opt_str(inc.get("cause"));
        md.push_str(&format!(
            "### #{} {} ({}) — {}s\n\n",
            fmt_num(inc.get("id").as_f64()),
            fmt_opt_str(inc.get("kind")),
            fmt_opt_str(inc.get("severity")),
            fmt_num(inc.get("duration_s").as_f64()),
        ));
        if let Some(detail) = inc.get("detail").as_str() {
            md.push_str(&format!("{detail}\n\n"));
        }
        md.push_str(&format!(
            "- **Root cause:** `{cause}` (window classified \
             `{bottleneck}`)\n",
        ));
        if let Some(att) = report.get("attribution").as_obj() {
            if let Some(ranked) = att
                .get("ranked_ttft_causes")
                .and_then(|j| j.as_arr())
            {
                if !ranked.is_empty() && cause != "fault" {
                    let names: Vec<String> = ranked
                        .iter()
                        .take(2)
                        .filter_map(|r| {
                            r.get("cause")
                                .as_str()
                                .map(|s| s.to_string())
                        })
                        .collect();
                    if !names.is_empty() {
                        md.push_str(&format!(
                            "- **Run-wide attribution concurs:** top \
                             TTFT causes {}\n",
                            names.join(", ")
                        ));
                    }
                }
            }
        }
        md.push_str(&format!(
            "- **Remediation:** {}\n\n",
            remediation(&bottleneck, &cause)
        ));
    }
    md
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(t: f64, replica: f64, down: f64, att: f64) -> Json {
        Json::obj(vec![
            ("t", Json::Num(t)),
            ("replica", Json::Num(replica)),
            ("down", Json::Num(down)),
            ("online_queue", Json::Num(3.0)),
            ("offline_backlog", Json::Num(0.0)),
            ("link_utilization", Json::arr_f64(&[0.7])),
            ("slo_attainment", Json::Num(att)),
        ])
    }

    #[test]
    fn passthrough_prefers_recorded_ledger() {
        let ledger = Json::obj(vec![
            ("total", Json::Num(2.0)),
            ("incidents", Json::Arr(vec![])),
        ]);
        let report =
            Json::obj(vec![("incidents", ledger.clone())]);
        assert_eq!(
            ledger_from_report(&report).to_pretty(),
            ledger.to_pretty()
        );
    }

    #[test]
    fn derives_fault_and_burn_windows_from_gauges() {
        // Replica 0 crashes from t=60..120; attainment collapses there.
        let mut rows = Vec::new();
        for k in 0..40 {
            let t = 5.0 * (k + 1) as f64;
            let down = if (60.0..120.0).contains(&t) { 1.0 } else { 0.0 };
            let att = if (60.0..150.0).contains(&t) { 0.4 } else { 1.0 };
            rows.push(sample(t, 0.0, down, att));
            rows.push(sample(t, 1.0, 0.0, att));
        }
        let report =
            Json::obj(vec![("timeline", Json::Arr(rows))]);
        let ledger = ledger_from_report(&report);
        assert_eq!(ledger.get("derived").as_bool(), Some(true));
        let incidents = ledger.get("incidents").as_arr().unwrap();
        let kinds: Vec<&str> = incidents
            .iter()
            .filter_map(|i| i.get("kind").as_str())
            .collect();
        assert!(kinds.contains(&"fault"), "kinds: {kinds:?}");
        assert!(kinds.contains(&"slo_burn"), "kinds: {kinds:?}");
        // The fault window must overlap the crash.
        let fault = incidents
            .iter()
            .find(|i| i.get("kind").as_str() == Some("fault"))
            .unwrap();
        let lo = fault.get("opened_at").as_f64().unwrap();
        let hi = fault.get("closed_at").as_f64().unwrap();
        assert!(lo >= 55.0 && lo <= 65.0, "opened_at {lo}");
        assert!(hi >= 115.0 && hi <= 125.0, "closed_at {hi}");
        // Markdown renders with the derived-note and both sections.
        let md = postmortem_md(&report, &ledger);
        assert!(md.contains("re-derived from the gauge timeline"));
        assert!(md.contains("## Incident timeline"));
        assert!(md.contains("## Top incidents"));
        assert!(md.contains("Remediation"));
    }

    #[test]
    fn quiet_run_renders_a_quiet_postmortem() {
        let report = Json::obj(vec![(
            "timeline",
            Json::Arr(vec![sample(5.0, 0.0, 0.0, 1.0)]),
        )]);
        let ledger = ledger_from_report(&report);
        assert_eq!(ledger.get("total").as_f64(), Some(0.0));
        let md = postmortem_md(&report, &ledger);
        assert!(md.contains("No incidents"));
    }

    #[test]
    fn remediation_covers_every_label() {
        for label in
            ["fault", "transfer", "memory_bw", "compute", "queue", "idle"]
        {
            assert!(!remediation(label, "unknown").is_empty());
        }
        assert!(remediation("queue", "pd_imbalance")
            .contains("strict/relaxed"));
    }
}
