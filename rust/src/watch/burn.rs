//! Multi-window burn-rate SLO alerting (DESIGN.md §3.12).
//!
//! The SRE playbook's multi-window, multi-burn-rate alert adapted to the
//! virtual clock: each detector keeps a rolling deque of per-completion
//! outcomes for one SLO metric (TTFT or TPOT), evaluates the violation
//! fraction over a *fast* window (is it still happening?) and a *slow*
//! window (is it significant?), and normalizes both by the error budget
//! (`slo.violation_threshold`). An incident opens only when **both**
//! windows exceed their burn thresholds; it closes only after the fast
//! burn has stayed under *half* its open threshold for
//! [`WatchParams::clear_ticks`] consecutive evaluations — readings inside
//! the half-to-full band keep the incident open and reset the cool-down,
//! which is the hysteresis that prevents flapping on a
//! boundary-oscillating trace (pinned by `tests/watch_properties.rs`).

use std::collections::VecDeque;

use super::WatchParams;

/// Burn rates over the two windows, in multiples of the error budget.
#[derive(Debug, Clone, Copy, Default)]
pub struct BurnRates {
    pub fast: f64,
    pub slow: f64,
}

/// State transition reported by one [`BurnDetector::tick`].
#[derive(Debug, Clone, Copy)]
pub enum BurnEvent {
    Opened { at: f64, fast: f64, slow: f64 },
    Closed { at: f64, peak: f64 },
}

/// One metric's (TTFT or TPOT) multi-window burn-rate state machine.
#[derive(Debug)]
pub struct BurnDetector {
    #[allow(dead_code)] // diagnostic tag, useful in Debug output
    metric: &'static str,
    /// `(completion time, violated)` outcomes, evicted beyond the slow
    /// window.
    window: VecDeque<(f64, bool)>,
    open: bool,
    /// Consecutive clear evaluations while open (resets inside the
    /// hysteresis band).
    cool: u32,
    /// Peak fast-window burn observed while open.
    peak: f64,
}

impl BurnDetector {
    pub fn new(metric: &'static str) -> Self {
        BurnDetector {
            metric,
            window: VecDeque::new(),
            open: false,
            cool: 0,
            peak: 0.0,
        }
    }

    /// Fold one completion outcome in (called between ticks).
    pub fn on_complete(&mut self, now: f64, violated: bool) {
        self.window.push_back((now, violated));
    }

    /// Current burn rates at `now`. Both read 0 until the slow window
    /// holds [`WatchParams::min_window_completions`] outcomes, so a lone
    /// early violation cannot page.
    pub fn rates(&self, now: f64, p: &WatchParams) -> BurnRates {
        let slow_cut = now - p.slow_window_s;
        let fast_cut = now - p.fast_window_s;
        let (mut sn, mut sv, mut fn_, mut fv) = (0usize, 0usize, 0usize, 0usize);
        for &(t, bad) in &self.window {
            if t < slow_cut {
                continue;
            }
            sn += 1;
            sv += bad as usize;
            if t >= fast_cut {
                fn_ += 1;
                fv += bad as usize;
            }
        }
        if sn < p.min_window_completions {
            return BurnRates::default();
        }
        let budget = p.budget();
        let frac = |v: usize, n: usize| {
            if n == 0 {
                0.0
            } else {
                v as f64 / n as f64
            }
        };
        BurnRates {
            fast: frac(fv, fn_) / budget,
            slow: frac(sv, sn) / budget,
        }
    }

    /// Peak fast burn observed during the currently open incident.
    pub fn peak(&self) -> f64 {
        self.peak
    }

    /// Evaluate at a sampler tick; evicts stale outcomes and runs the
    /// open/close state machine.
    pub fn tick(&mut self, now: f64, p: &WatchParams) -> Option<BurnEvent> {
        while let Some(&(t, _)) = self.window.front() {
            if t < now - p.slow_window_s {
                self.window.pop_front();
            } else {
                break;
            }
        }
        let r = self.rates(now, p);
        if !self.open {
            if r.fast >= p.fast_burn && r.slow >= p.slow_burn {
                self.open = true;
                self.cool = 0;
                self.peak = r.fast;
                return Some(BurnEvent::Opened {
                    at: now,
                    fast: r.fast,
                    slow: r.slow,
                });
            }
            return None;
        }
        self.peak = self.peak.max(r.fast);
        if r.fast <= 0.5 * p.fast_burn {
            self.cool += 1;
            if self.cool >= p.clear_ticks {
                self.open = false;
                let peak = self.peak;
                self.cool = 0;
                return Some(BurnEvent::Closed { at: now, peak });
            }
        } else {
            // Inside (or above) the hysteresis band: stay open, restart
            // the cool-down.
            self.cool = 0;
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SloSpec;

    fn params() -> WatchParams {
        WatchParams::new(SloSpec::default())
    }

    fn feed(det: &mut BurnDetector, t0: f64, n: usize, violated: bool) {
        for i in 0..n {
            det.on_complete(t0 + i as f64 * 0.1, violated);
        }
    }

    #[test]
    fn opens_only_when_both_windows_burn() {
        let p = params();
        let mut d = BurnDetector::new("ttft");
        // Violations confined to the distant past of the slow window:
        // slow burns, fast does not → no incident.
        feed(&mut d, 0.0, 50, true);
        feed(&mut d, 150.0, 50, false);
        assert!(d.tick(200.0, &p).is_none());
        // Fresh violations light both windows.
        feed(&mut d, 200.0, 50, true);
        assert!(matches!(
            d.tick(205.0, &p),
            Some(BurnEvent::Opened { .. })
        ));
    }

    #[test]
    fn thin_windows_never_page() {
        let p = params();
        let mut d = BurnDetector::new("ttft");
        feed(&mut d, 0.0, p.min_window_completions - 1, true);
        assert!(d.tick(1.0, &p).is_none());
    }

    #[test]
    fn hysteresis_band_keeps_incident_open_and_resets_cooldown() {
        let p = params();
        let mut d = BurnDetector::new("tpot");
        feed(&mut d, 0.0, 40, true);
        assert!(matches!(d.tick(5.0, &p), Some(BurnEvent::Opened { .. })));
        // Oscillate around the open threshold: mixed outcomes keep the
        // fast burn above half the threshold → never closes.
        let mut t = 10.0;
        for _ in 0..10 {
            feed(&mut d, t, 5, true);
            feed(&mut d, t + 1.0, 5, false);
            assert!(d.tick(t + 5.0, &p).is_none(), "flapped at t={t}");
            t += 5.0;
        }
        // Fully clean traffic for clear_ticks consecutive ticks closes it.
        let mut closed = None;
        for k in 0..(p.clear_ticks + 2) {
            feed(&mut d, t, 30, false);
            t += p.fast_window_s;
            if let Some(ev) = d.tick(t, &p) {
                closed = Some((k, ev));
                break;
            }
        }
        let (_, ev) = closed.expect("incident never closed");
        assert!(matches!(ev, BurnEvent::Closed { .. }));
    }

    #[test]
    fn peak_tracks_the_worst_fast_window() {
        let p = params();
        let mut d = BurnDetector::new("ttft");
        feed(&mut d, 0.0, 40, true);
        d.tick(5.0, &p);
        assert!(d.peak() > 0.0);
        // All-violating fast window: burn = 1/budget ≈ 33x.
        assert!((d.peak() - 1.0 / p.budget()).abs() < 1e-9);
    }
}
