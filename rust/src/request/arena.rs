//! Generational arena for request-lifetime state (DESIGN.md §3.13).
//!
//! A slot-indexed store whose handles ([`GenId`]) carry a generation
//! counter: removing an entry bumps its slot's generation, so any handle
//! issued before the removal goes *stale* — `get`/`get_mut` return `None`
//! instead of silently aliasing whatever later took the slot. This is the
//! structural version of the staleness guards the event loops rely on:
//! a step-end or transfer event that outlives its step compares sequence
//! ids today, and an arena handle that outlives its entry compares
//! generations here. Both make index reuse (pool flips, crash/recover
//! churn) safe by construction.
//!
//! The free list recycles slots in LIFO order, so churn-heavy workloads
//! (millions of requests entering and leaving residency) run at a small
//! constant live footprint instead of growing the backing vec forever.

/// Generational handle into an [`Arena`]. `index` names the slot,
/// `generation` must match the slot's current generation to deref.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GenId {
    index: u32,
    generation: u32,
}

impl GenId {
    /// Slot index (stable for the entry's lifetime).
    pub fn index(self) -> u32 {
        self.index
    }

    /// Generation the handle was issued under.
    pub fn generation(self) -> u32 {
        self.generation
    }
}

#[derive(Debug)]
struct Slot<T> {
    generation: u32,
    value: Option<T>,
}

/// Generational slot arena: O(1) insert/get/remove, stale handles read
/// as absent, slots recycle through a LIFO free list.
#[derive(Debug)]
pub struct Arena<T> {
    slots: Vec<Slot<T>>,
    free: Vec<u32>,
    len: usize,
}

impl<T> Default for Arena<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Arena<T> {
    pub fn new() -> Self {
        Arena {
            slots: Vec::new(),
            free: Vec::new(),
            len: 0,
        }
    }

    pub fn with_capacity(cap: usize) -> Self {
        Arena {
            slots: Vec::with_capacity(cap),
            free: Vec::new(),
            len: 0,
        }
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Slots ever allocated (live + free).
    pub fn capacity_slots(&self) -> usize {
        self.slots.len()
    }

    /// Store `value`, reusing a freed slot when one exists.
    pub fn insert(&mut self, value: T) -> GenId {
        self.len += 1;
        if let Some(index) = self.free.pop() {
            let slot = &mut self.slots[index as usize];
            debug_assert!(slot.value.is_none(), "free-listed slot occupied");
            slot.value = Some(value);
            return GenId {
                index,
                generation: slot.generation,
            };
        }
        let index = u32::try_from(self.slots.len())
            .expect("arena exceeds u32 slot space");
        self.slots.push(Slot {
            generation: 0,
            value: Some(value),
        });
        GenId {
            index,
            generation: 0,
        }
    }

    /// Is `id` still the live entry it was issued for?
    pub fn contains(&self, id: GenId) -> bool {
        self.slots
            .get(id.index as usize)
            .map(|s| s.generation == id.generation && s.value.is_some())
            .unwrap_or(false)
    }

    /// Read through the handle; `None` when stale (removed, or the slot
    /// was reused under a newer generation).
    pub fn get(&self, id: GenId) -> Option<&T> {
        let slot = self.slots.get(id.index as usize)?;
        if slot.generation != id.generation {
            return None;
        }
        slot.value.as_ref()
    }

    pub fn get_mut(&mut self, id: GenId) -> Option<&mut T> {
        let slot = self.slots.get_mut(id.index as usize)?;
        if slot.generation != id.generation {
            return None;
        }
        slot.value.as_mut()
    }

    /// Remove the entry behind `id`, bumping the slot's generation so
    /// every outstanding copy of `id` goes stale. `None` when already
    /// stale — removal is idempotent per generation.
    pub fn remove(&mut self, id: GenId) -> Option<T> {
        let slot = self.slots.get_mut(id.index as usize)?;
        if slot.generation != id.generation {
            return None;
        }
        let value = slot.value.take()?;
        slot.generation = slot.generation.wrapping_add(1);
        self.free.push(id.index);
        self.len -= 1;
        Some(value)
    }

    /// Iterate live entries in slot order.
    pub fn iter(&self) -> impl Iterator<Item = (GenId, &T)> {
        self.slots.iter().enumerate().filter_map(|(i, s)| {
            s.value.as_ref().map(|v| {
                (
                    GenId {
                        index: i as u32,
                        generation: s.generation,
                    },
                    v,
                )
            })
        })
    }
}

/// Bounded LIFO pool of cleared-but-capacity-retaining buffers — the
/// allocation-recycling companion the scheduler core uses for its action
/// and step-body vecs (DESIGN.md §3.13).
#[derive(Debug)]
pub struct Recycler<T> {
    spare: Vec<T>,
    cap: usize,
}

impl<T> Recycler<T> {
    pub fn new(cap: usize) -> Self {
        Recycler {
            spare: Vec::new(),
            cap,
        }
    }

    /// Take a recycled value, if any.
    pub fn take(&mut self) -> Option<T> {
        self.spare.pop()
    }

    /// Return a spent value to the pool; dropped when the pool is full.
    pub fn put(&mut self, value: T) {
        if self.spare.len() < self.cap {
            self.spare.push(value);
        }
    }

    pub fn len(&self) -> usize {
        self.spare.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spare.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut a: Arena<&'static str> = Arena::new();
        let x = a.insert("x");
        let y = a.insert("y");
        assert_eq!(a.len(), 2);
        assert_eq!(a.get(x), Some(&"x"));
        assert_eq!(a.get(y), Some(&"y"));
        assert_eq!(a.remove(x), Some("x"));
        assert_eq!(a.len(), 1);
        assert_eq!(a.get(x), None);
        assert!(!a.contains(x));
        assert!(a.contains(y));
    }

    #[test]
    fn stale_handle_cannot_alias_slot_reuse() {
        let mut a: Arena<u64> = Arena::new();
        let old = a.insert(1);
        a.remove(old).unwrap();
        // LIFO free list: the next insert reuses the same slot...
        let new = a.insert(2);
        assert_eq!(new.index(), old.index());
        // ...under a newer generation, so the old handle stays dead.
        assert_ne!(new.generation(), old.generation());
        assert_eq!(a.get(old), None);
        assert_eq!(a.remove(old), None, "stale removal is a no-op");
        assert_eq!(a.get(new), Some(&2));
    }

    #[test]
    fn double_remove_is_idempotent() {
        let mut a: Arena<u8> = Arena::new();
        let id = a.insert(7);
        assert_eq!(a.remove(id), Some(7));
        assert_eq!(a.remove(id), None);
        assert_eq!(a.len(), 0);
    }

    #[test]
    fn footprint_stays_bounded_under_churn() {
        let mut a: Arena<u64> = Arena::new();
        for round in 0..1000u64 {
            let ids: Vec<GenId> =
                (0..4).map(|i| a.insert(round * 4 + i)).collect();
            for (i, id) in ids.into_iter().enumerate() {
                assert_eq!(a.remove(id), Some(round * 4 + i as u64));
            }
        }
        // At most 4 entries were ever live at once.
        assert!(a.capacity_slots() <= 4, "slots {}", a.capacity_slots());
        assert_eq!(a.len(), 0);
    }

    #[test]
    fn iter_visits_live_entries_only() {
        let mut a: Arena<u32> = Arena::new();
        let x = a.insert(10);
        let _y = a.insert(20);
        let z = a.insert(30);
        a.remove(x).unwrap();
        a.remove(z).unwrap();
        let live: Vec<u32> = a.iter().map(|(_, v)| *v).collect();
        assert_eq!(live, vec![20]);
    }

    #[test]
    fn recycler_bounds_and_recycles() {
        let mut r: Recycler<Vec<u8>> = Recycler::new(2);
        assert!(r.take().is_none());
        r.put(Vec::with_capacity(8));
        r.put(Vec::with_capacity(16));
        r.put(Vec::with_capacity(32)); // over cap: dropped
        assert_eq!(r.len(), 2);
        let v = r.take().unwrap();
        assert!(v.capacity() >= 16);
        assert!(!r.is_empty());
    }
}
