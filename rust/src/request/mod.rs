//! Request types shared by the simulator and the real engine.
//!
//! Requests carry their class (online = latency-sensitive, offline =
//! cost-sensitive), prompt/output lengths, and the timing milestones the
//! metrics layer turns into TTFT/TPOT/SLO statistics.

pub mod arena;

pub use arena::{Arena, GenId, Recycler};

/// Unique request id.
pub type RequestId = u64;

/// Service class — the axis the whole paper pivots on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Class {
    /// Latency-sensitive: TTFT + TPOT SLOs apply.
    Online,
    /// Cost-sensitive batch work: no per-request latency constraints.
    Offline,
}

impl Class {
    pub fn is_online(self) -> bool {
        matches!(self, Class::Online)
    }

    pub fn name(self) -> &'static str {
        match self {
            Class::Online => "online",
            Class::Offline => "offline",
        }
    }
}

/// Lifecycle phase of a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Waiting for prefill.
    Queued,
    /// Prefill running on a latency-relaxed instance.
    Prefilling,
    /// KV cache in flight between instances.
    Migrating,
    /// Decoding (on either pool, per the latency-constraint rules).
    Decoding,
    /// All output tokens produced.
    Finished,
}

/// A shareable-prompt declaration (DESIGN.md §3.7): the first `len`
/// tokens of this request's prompt are — by construction of the trace —
/// the same tokens as every other request declaring `family` (a shared
/// system prompt, a few-shot template, or the growing context of one
/// agentic conversation). The prefix cache keys hashed token blocks by
/// `(family, block index)`, the identity stand-in for a content hash.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixRef {
    pub family: u64,
    /// Shareable span in tokens (≤ `prompt_len`).
    pub len: usize,
}

/// A single inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub class: Class,
    /// Arrival time (s since experiment start).
    pub arrival: f64,
    /// Prompt length in tokens.
    pub prompt_len: usize,
    /// Number of output tokens this request will generate (known in traces;
    /// in the real engine it is the generation limit).
    pub output_len: usize,
    pub phase: Phase,
    /// Output tokens generated so far.
    pub generated: usize,
    /// Time the first token was produced (prefill completion), if any.
    pub first_token_at: Option<f64>,
    /// Completion time, if finished.
    pub finished_at: Option<f64>,
    /// Times this request's offline work was evicted and re-prefilled
    /// (recompute overhead accounting).
    pub evictions: u32,
    /// Shared-prompt declaration for the prefix cache, if any.
    pub prefix: Option<PrefixRef>,
    /// Chunked-prefill progress cursor: prompt tokens of the *current*
    /// prefill attempt already computed or served from the prefix cache.
    /// Reset on eviction (recompute restarts the attempt).
    pub prefilled_tokens: usize,
    /// Prompt tokens the current prefill attempt must cover — the
    /// recompute length frozen at admission. 0 = not admitted.
    pub prefill_target: usize,
    /// Prefix-cache credit of the current attempt (tokens of
    /// `prefilled_tokens` that were never computed). Lets metrics count
    /// *computed* progress only.
    pub prefill_cached: usize,
}

impl Request {
    pub fn new(
        id: RequestId,
        class: Class,
        arrival: f64,
        prompt_len: usize,
        output_len: usize,
    ) -> Self {
        Request {
            id,
            class,
            arrival,
            prompt_len: prompt_len.max(1),
            output_len: output_len.max(1),
            phase: Phase::Queued,
            generated: 0,
            first_token_at: None,
            finished_at: None,
            evictions: 0,
            prefix: None,
            prefilled_tokens: 0,
            prefill_target: 0,
            prefill_cached: 0,
        }
    }

    /// Declare the first `len` prompt tokens as `family`'s shared prefix
    /// (clamped to the prompt length).
    pub fn with_prefix(mut self, family: u64, len: usize) -> Self {
        self.prefix = Some(PrefixRef {
            family,
            len: len.min(self.prompt_len),
        });
        self
    }

    /// Current KV length: prompt + tokens generated so far.
    pub fn kv_len(&self) -> usize {
        self.prompt_len + self.generated
    }

    /// Total tokens this request will ever hold in KV.
    pub fn final_kv_len(&self) -> usize {
        self.prompt_len + self.output_len
    }

    pub fn is_finished(&self) -> bool {
        self.generated >= self.output_len
    }

    /// Record prefill completion (first token) at time `t`.
    pub fn mark_first_token(&mut self, t: f64) {
        if self.first_token_at.is_none() {
            self.first_token_at = Some(t);
            self.generated = self.generated.max(1);
        }
    }

    /// Record one decode-step token at time `t`; returns true if finished.
    pub fn mark_token(&mut self, t: f64) -> bool {
        self.generated += 1;
        if self.is_finished() {
            self.finished_at = Some(t);
            self.phase = Phase::Finished;
            true
        } else {
            false
        }
    }

    /// Reset progress after an eviction: KV is dropped, prefill must rerun.
    /// Already-generated tokens are part of the recompute prompt (the
    /// standard recompute-on-restore semantics).
    pub fn evict(&mut self) {
        debug_assert!(!self.is_finished());
        self.evictions += 1;
        self.phase = Phase::Queued;
        self.prefilled_tokens = 0;
        self.prefill_target = 0;
        self.prefill_cached = 0;
    }

    /// Open a prefill attempt covering `target` tokens, of which `cached`
    /// were served from the prefix cache. At least one token is always
    /// computed (a fully cached prompt still runs its query token), so the
    /// cache credit is capped at `target - 1`.
    pub fn begin_prefill(&mut self, target: usize, cached: usize) {
        let target = target.max(1);
        self.prefill_target = target;
        self.prefilled_tokens = cached.min(target - 1);
        self.prefill_cached = self.prefilled_tokens;
    }

    /// Prompt tokens of the current attempt actually computed so far
    /// (cursor minus the prefix-cache credit).
    pub fn computed_prefill(&self) -> usize {
        self.prefilled_tokens.saturating_sub(self.prefill_cached)
    }

    /// Credit `tokens` of computed prefill work to the cursor. Deliberately
    /// unclamped: a cursor past the target means a chunk was double-counted
    /// somewhere, and the completion check must be able to see it.
    pub fn advance_prefill(&mut self, tokens: usize) {
        self.prefilled_tokens += tokens;
    }

    /// Prompt tokens of the current attempt still to compute.
    pub fn remaining_prefill(&self) -> usize {
        self.prefill_target.saturating_sub(self.prefilled_tokens)
    }

    /// Prompt length a re-prefill after eviction must process.
    pub fn recompute_len(&self) -> usize {
        self.kv_len()
    }

    /// TTFT if the first token has been produced.
    pub fn ttft(&self) -> Option<f64> {
        self.first_token_at.map(|t| t - self.arrival)
    }

    /// Average TPOT over the decode phase (needs >= 2 tokens).
    pub fn avg_tpot(&self) -> Option<f64> {
        match (self.first_token_at, self.finished_at) {
            (Some(first), Some(done)) if self.output_len > 1 => {
                Some((done - first) / (self.output_len - 1) as f64)
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_and_metrics() {
        let mut r = Request::new(1, Class::Online, 10.0, 100, 5);
        assert_eq!(r.kv_len(), 100);
        assert_eq!(r.final_kv_len(), 105);
        r.mark_first_token(12.0);
        assert_eq!(r.ttft(), Some(2.0));
        assert_eq!(r.generated, 1);
        assert_eq!(r.kv_len(), 101);
        for i in 0..3 {
            assert!(!r.mark_token(13.0 + i as f64));
        }
        assert!(r.mark_token(16.0));
        assert_eq!(r.finished_at, Some(16.0));
        // 4 decode tokens over (16 - 12) s -> 1 s/token
        assert_eq!(r.avg_tpot(), Some(1.0));
        assert_eq!(r.phase, Phase::Finished);
    }

    #[test]
    fn first_token_recorded_once() {
        let mut r = Request::new(1, Class::Online, 0.0, 10, 3);
        r.mark_first_token(1.0);
        r.mark_first_token(2.0);
        assert_eq!(r.first_token_at, Some(1.0));
    }

    #[test]
    fn eviction_recompute() {
        let mut r = Request::new(2, Class::Offline, 0.0, 200, 100);
        r.mark_first_token(5.0);
        r.mark_token(6.0);
        assert_eq!(r.generated, 2);
        r.evict();
        assert_eq!(r.evictions, 1);
        assert_eq!(r.phase, Phase::Queued);
        // Recompute must re-process prompt + generated tokens.
        assert_eq!(r.recompute_len(), 202);
    }

    #[test]
    fn zero_lengths_clamped() {
        let r = Request::new(3, Class::Offline, 0.0, 0, 0);
        assert_eq!(r.prompt_len, 1);
        assert_eq!(r.output_len, 1);
    }

    #[test]
    fn tpot_requires_completion() {
        let mut r = Request::new(4, Class::Online, 0.0, 10, 1);
        assert_eq!(r.avg_tpot(), None);
        r.mark_first_token(1.0);
        r.finished_at = Some(1.0);
        // output_len == 1 -> no decode phase -> no TPOT.
        assert_eq!(r.avg_tpot(), None);
    }

    #[test]
    fn prefix_declaration_clamps_to_prompt() {
        let r = Request::new(5, Class::Offline, 0.0, 100, 10)
            .with_prefix(42, 4000);
        let p = r.prefix.unwrap();
        assert_eq!(p.family, 42);
        assert_eq!(p.len, 100);
        assert!(Request::new(6, Class::Offline, 0.0, 100, 10)
            .prefix
            .is_none());
    }

    #[test]
    fn prefill_cursor_lifecycle() {
        let mut r = Request::new(7, Class::Offline, 0.0, 1000, 4);
        assert_eq!(r.remaining_prefill(), 0); // not admitted yet
        r.begin_prefill(1000, 0);
        assert_eq!(r.remaining_prefill(), 1000);
        r.advance_prefill(600);
        assert_eq!(r.remaining_prefill(), 400);
        r.advance_prefill(400);
        assert_eq!(r.remaining_prefill(), 0);
        assert_eq!(r.prefilled_tokens, r.prefill_target);
        // Eviction resets the attempt.
        r.evict();
        assert_eq!(r.prefilled_tokens, 0);
        assert_eq!(r.prefill_target, 0);
        // Cache credit is capped so one query token always runs.
        let mut c = Request::new(8, Class::Online, 0.0, 512, 4);
        c.begin_prefill(512, 512);
        assert_eq!(c.remaining_prefill(), 1);
    }

    #[test]
    fn class_helpers() {
        assert!(Class::Online.is_online());
        assert!(!Class::Offline.is_online());
        assert_eq!(Class::Offline.name(), "offline");
    }
}
