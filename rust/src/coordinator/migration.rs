//! Offline Request Migration — Algorithm 1 (pull model).
//!
//! A latency-strict node that (a) is under the TPOT bound with margin and
//! (b) already includes every resident request in its decode batch, derives
//! a *length preference* from its performance bottleneck and pulls matching
//! offline decodes from a latency-relaxed node:
//!
//! - compute-saturated (`bs(B) >= bs_sat`): growing the batch no longer
//!   helps -> fill memory instead: prefer the **longest** requests that keep
//!   `L(B ∪ r) <= S` and fit capacity;
//! - not saturated, and saturation reachable within the SLO: prefer the
//!   **longest length that still fits** (max permissible under S);
//! - not saturated and unreachable: prefer the **shortest** requests to
//!   maximize batch growth.

use crate::perfmodel::{BatchStats, PerfModel};
use crate::request::RequestId;

use super::mix_decode::Candidate;

/// The strict node's advertised preference for pulled offline requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LengthPref {
    /// No migration this step.
    None,
    /// Prefer requests as long as possible but `<= max_len` tokens.
    LongestUpTo { max_len: usize },
    /// Prefer the shortest available requests.
    Shortest,
}

/// Algorithm 1: derive the length preference. `batch` describes the current
/// decode batch B; `all_included` is the line-2 condition ("all requests in
/// node are included in B"); `slo_bound` is S.
pub fn migration_decision(
    pm: &PerfModel,
    batch: BatchStats,
    all_included: bool,
    slo_bound: f64,
    margin: f64,
) -> LengthPref {
    let bound = slo_bound * (1.0 - margin);
    if !all_included || pm.decode_latency(batch) >= bound {
        return LengthPref::None; // line 16: Pref <- ∅
    }
    let bs_sat = pm.bs_sat();

    // Largest single-request KV length admissible under S (and capacity).
    let max_admissible = max_admissible_len(pm, batch, bound);
    if max_admissible == 0 {
        return LengthPref::None;
    }

    if batch.size >= bs_sat {
        // Compute-saturated: objective shifts to filling memory capacity.
        LengthPref::LongestUpTo {
            max_len: max_admissible,
        }
    } else {
        // Can a group of requests reach compute saturation within the SLO?
        // Conservatively test with short requests (most batch per token).
        let need = bs_sat - batch.size;
        let short = 64usize; // a freshly-started offline decode
        let saturated = batch.with_group(need, need * short);
        if pm.decode_latency(saturated) <= bound
            && pm.memory_utilization(saturated) <= 1.0
        {
            // Saturation reachable: take the longest lengths that fit.
            LengthPref::LongestUpTo {
                max_len: max_admissible,
            }
        } else {
            // Unreachable: maximize batch size with the shortest requests.
            LengthPref::Shortest
        }
    }
}

/// Binary-search the largest per-request KV length `l` with
/// `L(B ∪ r_l) <= bound` and memory fitting.
fn max_admissible_len(pm: &PerfModel, batch: BatchStats, bound: f64) -> usize {
    let fits = |l: usize| {
        let b = batch.with(l);
        pm.decode_latency(b) <= bound && pm.memory_utilization(b) <= 1.0
    };
    if !fits(1) {
        return 0;
    }
    let mut lo = 1usize;
    let mut hi = 2usize;
    let cap = pm.max_kv_tokens().max(2);
    while hi < cap && fits(hi) {
        lo = hi;
        hi *= 2;
    }
    let mut hi = hi.min(cap);
    if fits(hi) {
        return hi;
    }
    while lo + 1 < hi {
        let mid = lo + (hi - lo) / 2;
        if fits(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Relaxed-node side: pick up to `max_count` of its decoding offline
/// requests "most closed to Pref" (paper line 14).
pub fn pick_migration_candidates(
    pref: LengthPref,
    candidates: &[Candidate],
    max_count: usize,
) -> Vec<RequestId> {
    if max_count == 0 || candidates.is_empty() {
        return vec![];
    }
    match pref {
        LengthPref::None => vec![],
        LengthPref::Shortest => {
            let mut sorted: Vec<Candidate> = candidates.to_vec();
            sorted.sort_unstable_by_key(|c| c.1);
            sorted.iter().take(max_count).map(|c| c.0).collect()
        }
        LengthPref::LongestUpTo { max_len } => {
            // Longest-first among those within the cap.
            let mut eligible: Vec<Candidate> = candidates
                .iter()
                .filter(|c| c.1 <= max_len)
                .copied()
                .collect();
            eligible.sort_unstable_by(|a, b| b.1.cmp(&a.1));
            eligible.iter().take(max_count).map(|c| c.0).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareProfile, ModelSpec};

    fn pm() -> PerfModel {
        PerfModel::new(ModelSpec::qwen2_5_7b(), HardwareProfile::ascend_910c())
    }

    const SLO: f64 = 0.1;

    #[test]
    fn no_migration_when_busy_or_not_all_included() {
        let pm = pm();
        // Batch already at/over the bound -> None.
        let heavy = BatchStats::new(900, 900 * 3000);
        assert!(pm.decode_latency(heavy) > SLO * 0.9);
        assert_eq!(
            migration_decision(&pm, heavy, true, SLO, 0.1),
            LengthPref::None
        );
        // Not all requests included -> None even when idle-ish.
        let light = BatchStats::new(4, 4000);
        assert_eq!(
            migration_decision(&pm, light, false, SLO, 0.1),
            LengthPref::None
        );
    }

    #[test]
    fn saturated_batch_prefers_longest() {
        let pm = pm();
        let sat = pm.bs_sat();
        let batch = BatchStats::new(sat + 10, (sat + 10) * 100); // short kvs
        assert!(pm.decode_latency(batch) < SLO * 0.9, "precondition");
        match migration_decision(&pm, batch, true, SLO, 0.1) {
            LengthPref::LongestUpTo { max_len } => {
                assert!(max_len > 1000, "max_len {max_len}");
                // The advertised length must actually fit under the bound.
                let b = batch.with(max_len);
                assert!(pm.decode_latency(b) <= SLO * 0.9 + 1e-12);
            }
            other => panic!("expected LongestUpTo, got {other:?}"),
        }
    }

    #[test]
    fn small_batch_reachable_saturation_prefers_long_within_slo() {
        let pm = pm();
        let batch = BatchStats::new(4, 4 * 500);
        let pref = migration_decision(&pm, batch, true, SLO, 0.1);
        // With a 100ms bound, saturation is reachable on this profile.
        assert!(
            matches!(pref, LengthPref::LongestUpTo { .. }),
            "got {pref:?}"
        );
    }

    #[test]
    fn tight_slo_unreachable_saturation_prefers_shortest() {
        let pm = pm();
        // A bound barely above the empty-batch latency: saturation would
        // blow it, so the preference must be Shortest.
        let batch = BatchStats::new(2, 200);
        let base = pm.decode_latency(batch);
        let tight = base * 1.03;
        let pref = migration_decision(&pm, batch, true, tight / 0.9, 0.1);
        // (bound after margin == tight)
        match pref {
            LengthPref::Shortest => {}
            LengthPref::None => {} // acceptable when nothing fits
            other => panic!("expected Shortest/None, got {other:?}"),
        }
    }

    #[test]
    fn max_admissible_len_is_maximal() {
        let pm = pm();
        let batch = BatchStats::new(50, 50 * 800);
        let bound = 0.08;
        let l = max_admissible_len(&pm, batch, bound);
        assert!(l > 0);
        assert!(pm.decode_latency(batch.with(l)) <= bound);
        assert!(
            pm.decode_latency(batch.with(l + l / 100 + 8)) > bound
                || pm.memory_utilization(batch.with(l + l / 100 + 8)) > 1.0
        );
    }

    #[test]
    fn candidate_picking() {
        let cands: Vec<Candidate> =
            vec![(1, 100), (2, 5000), (3, 800), (4, 2500), (5, 300)];
        // Shortest: ids by ascending length.
        assert_eq!(
            pick_migration_candidates(LengthPref::Shortest, &cands, 2),
            vec![1, 5]
        );
        // LongestUpTo 2600: eligible {100,800,2500,300}, longest first.
        assert_eq!(
            pick_migration_candidates(
                LengthPref::LongestUpTo { max_len: 2600 },
                &cands,
                2
            ),
            vec![4, 3]
        );
        // None / empty.
        assert!(pick_migration_candidates(LengthPref::None, &cands, 3).is_empty());
        assert!(
            pick_migration_candidates(LengthPref::Shortest, &cands, 0).is_empty()
        );
        assert!(pick_migration_candidates(LengthPref::Shortest, &[], 3).is_empty());
    }

    #[test]
    fn picked_candidates_respect_pref_property() {
        crate::testutil::forall(40, |r| {
            let n = r.below(30) + 1;
            let cands: Vec<Candidate> = (0..n)
                .map(|i| (i as u64, r.below(4000) + 1))
                .collect();
            let max_len = r.below(4000) + 1;
            let picked = pick_migration_candidates(
                LengthPref::LongestUpTo { max_len },
                &cands,
                r.below(6) + 1,
            );
            for id in &picked {
                let c = cands.iter().find(|c| c.0 == *id).unwrap();
                crate::prop_assert!(
                    c.1 <= max_len,
                    "picked over-length candidate {} > {max_len}",
                    c.1
                );
            }
            // No duplicates.
            let mut p = picked.clone();
            p.sort_unstable();
            p.dedup();
            crate::prop_assert!(p.len() == picked.len(), "duplicates");
            Ok(())
        });
    }
}
