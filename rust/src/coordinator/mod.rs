//! The OOCO coordinator: the paper's §3.4 scheduling logic as pure,
//! instance-agnostic decision functions, shared by the discrete-event
//! simulator (`sim`) and the real PJRT engine (`engine`).
//!
//! Four scheduling points on the data path (Fig. 4):
//! - [`preemption`] — online request preemption (layer-level interruption +
//!   bottleneck-aware eviction);
//! - [`gating`] — offline request gating cost model;
//! - [`migration`] — offline request migration, Algorithm 1 (pull model);
//! - [`mix_decode`] — mix decoding selection, Algorithm 2;
//!
//! plus [`policy`] (the three compared systems) and [`router`]
//! (request-level dispatch across instances, the xllm-service analog).

pub mod gating;
pub mod migration;
pub mod mix_decode;
pub mod policy;
pub mod preemption;
pub mod router;

pub use gating::{should_prefill_offline, GatingInput};
pub use migration::{migration_decision, pick_migration_candidates, LengthPref};
pub use mix_decode::{
    select_decode_batch, select_decode_batch_capped, shed_online_overload,
    Candidate, OverloadMode, Selection,
};
pub use policy::{Ablation, Policy};
pub use preemption::{preemption_delay, select_evictions};
pub use router::Router;
