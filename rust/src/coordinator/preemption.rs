//! Online request preemption (§3.4.1).
//!
//! Two mechanisms:
//!
//! 1. **Layer-level interruption** of offline prefill on latency-relaxed
//!    nodes: an arriving online request interrupts the running offline
//!    prefill at the next transformer-layer boundary — tens of ms, no
//!    model-specific surgery. [`preemption_delay`] computes the expected
//!    wait until that boundary.
//!
//! 2. **Bottleneck-aware eviction** of offline decodes on latency-strict
//!    nodes when an incoming online request needs KV space: if the node is
//!    compute-bound, evict *longer* requests (frees many tokens while
//!    shrinking the batch little); if memory-bandwidth-bound, evict
//!    *shorter* ones (cheaper recompute; batch size is not the binding
//!    resource).

use crate::perfmodel::{Bottleneck, PerfModel};
use crate::request::RequestId;

use super::mix_decode::Candidate;

/// Expected delay before an online prefill can start when an offline
/// prefill step is `elapsed_frac` (0..1) through on the instance: remaining
/// time of the *current layer* only.
pub fn preemption_delay(pm: &PerfModel, prompt_len: usize, elapsed_frac: f64) -> f64 {
    let per_layer = pm.prefill_layer_latency(prompt_len);
    let within = (elapsed_frac * pm.model.layers as f64).fract();
    per_layer * (1.0 - within)
}

/// Choose offline decode victims on a strict node to free at least
/// `needed_tokens` of KV. Returns victim ids (possibly fewer tokens than
/// requested if the pool is small).
///
/// `bottleneck_aware = false` gives the baseline behaviour (oldest-first ==
/// slice order).
pub fn select_evictions(
    pm: &PerfModel,
    victims: &[Candidate],
    needed_tokens: usize,
    bottleneck: Bottleneck,
    bottleneck_aware: bool,
) -> Vec<RequestId> {
    if needed_tokens == 0 || victims.is_empty() {
        return vec![];
    }
    let _ = pm;
    let mut order: Vec<Candidate> = victims.to_vec();
    if bottleneck_aware {
        match bottleneck {
            // Compute-bound: evict longest first (preserve batch size).
            Bottleneck::Compute => order.sort_unstable_by(|a, b| b.1.cmp(&a.1)),
            // Bandwidth-bound: evict shortest first (cheap recompute).
            Bottleneck::MemoryBandwidth => order.sort_unstable_by_key(|c| c.1),
        }
    }
    let mut freed = 0usize;
    let mut out = Vec::new();
    for (id, kv) in order {
        if freed >= needed_tokens {
            break;
        }
        out.push(id);
        freed += kv;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareProfile, ModelSpec};
    use crate::perfmodel::BatchStats;

    fn pm() -> PerfModel {
        PerfModel::new(ModelSpec::qwen2_5_7b(), HardwareProfile::ascend_910c())
    }

    #[test]
    fn preemption_delay_within_one_layer() {
        let pm = pm();
        let per_layer = pm.prefill_layer_latency(4000);
        for frac in [0.0, 0.13, 0.5, 0.97] {
            let d = preemption_delay(&pm, 4000, frac);
            assert!(d > 0.0 && d <= per_layer + 1e-12, "frac {frac} d {d}");
        }
        // Paper: "preemption within tens of milliseconds".
        assert!(preemption_delay(&pm, 4000, 0.0) < 0.05);
    }

    #[test]
    fn compute_bound_evicts_longest() {
        let pm = pm();
        let victims: Vec<Candidate> = vec![(1, 100), (2, 4000), (3, 900), (4, 2000)];
        let out = select_evictions(&pm, &victims, 4500, Bottleneck::Compute, true);
        // Longest first: 4000 then 2000 -> 6000 >= 4500 freed by two victims.
        assert_eq!(out, vec![2, 4]);
    }

    #[test]
    fn bandwidth_bound_evicts_shortest() {
        let pm = pm();
        let victims: Vec<Candidate> = vec![(1, 100), (2, 4000), (3, 900), (4, 2000)];
        let out =
            select_evictions(&pm, &victims, 800, Bottleneck::MemoryBandwidth, true);
        // Shortest first: 100 (not enough) then 900 -> done.
        assert_eq!(out, vec![1, 3]);
    }

    #[test]
    fn baseline_evicts_in_given_order() {
        let pm = pm();
        let victims: Vec<Candidate> = vec![(9, 50), (8, 5000), (7, 60)];
        let out = select_evictions(&pm, &victims, 40, Bottleneck::Compute, false);
        assert_eq!(out, vec![9]); // oldest-first regardless of bottleneck
    }

    #[test]
    fn eviction_edge_cases() {
        let pm = pm();
        assert!(select_evictions(&pm, &[], 100, Bottleneck::Compute, true).is_empty());
        assert!(
            select_evictions(&pm, &[(1, 10)], 0, Bottleneck::Compute, true).is_empty()
        );
        // Pool smaller than the need: evict everything available.
        let out = select_evictions(
            &pm,
            &[(1, 10), (2, 20)],
            1_000_000,
            Bottleneck::MemoryBandwidth,
            true,
        );
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn eviction_frees_enough_property() {
        let pm = pm();
        crate::testutil::forall(40, |r| {
            let n = r.below(40) + 1;
            let victims: Vec<Candidate> = (0..n)
                .map(|i| (i as u64, r.below(3000) + 1))
                .collect();
            let total: usize = victims.iter().map(|c| c.1).sum();
            let needed = r.below(total) + 1;
            let bn = if r.chance(0.5) {
                Bottleneck::Compute
            } else {
                Bottleneck::MemoryBandwidth
            };
            let out = select_evictions(&pm, &victims, needed, bn, true);
            let freed: usize = out
                .iter()
                .map(|id| victims.iter().find(|c| c.0 == *id).unwrap().1)
                .sum();
            crate::prop_assert!(
                freed >= needed.min(total),
                "freed {freed} < needed {needed}"
            );
            // Minimality-ish: dropping the last victim would under-free.
            if let Some(last) = out.last() {
                let last_kv = victims.iter().find(|c| c.0 == *last).unwrap().1;
                crate::prop_assert!(
                    freed - last_kv < needed,
                    "over-eviction: {freed} - {last_kv} still >= {needed}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn bottleneck_matches_perfmodel_classification() {
        let pm = pm();
        let sat = pm.bs_sat();
        assert_eq!(
            pm.decode_bottleneck(BatchStats::new(sat * 2, sat * 2 * 100)),
            Bottleneck::Compute
        );
        assert_eq!(
            pm.decode_bottleneck(BatchStats::new(2, 4000)),
            Bottleneck::MemoryBandwidth
        );
    }
}
