//! Mix Decoding Selection — Algorithm 2.
//!
//! Every strict-node decode iteration chooses its batch: all online requests
//! are included first, then offline candidates are admitted under the TPOT
//! SLO bound using the O(1) latency predictor:
//!
//! 1. up to K *random* probes (starvation avoidance — long requests that
//!    would lose a sorted admission still get sampled);
//! 2. remaining candidates sorted by ascending KV length;
//! 3. binary search for the largest prefix that still fits the bound
//!    (maximizing batch size when only part of the offline set fits).
//!
//! All probes are O(1) via [`BatchStats::with`]; the prefix step uses
//! [`PrefixSums::max_prefix`], so one selection costs
//! O(K + m log m) (sort) + O(log m) (search).

use crate::perfmodel::{BatchStats, PerfModel, PrefixSums};
use crate::request::RequestId;
use crate::util::rng::Pcg;

/// One decode candidate: request id + current KV length.
pub type Candidate = (RequestId, usize);

/// What to do when the online-only batch already exceeds the SLO bound
/// (§3.4.4: "this can be configured either to ignore the SLO and still
/// Decode all online requests (best-effort mode) or to sacrifice a portion
/// of requests in order to preserve the SLO for the remaining ones").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OverloadMode {
    /// Decode every online request even past the bound (default).
    #[default]
    BestEffort,
    /// Shed the longest online requests until the rest fit the bound.
    Shed,
}

impl std::str::FromStr for OverloadMode {
    type Err = anyhow::Error;

    fn from_str(name: &str) -> anyhow::Result<OverloadMode> {
        match name {
            "best-effort" | "best_effort" => Ok(OverloadMode::BestEffort),
            "shed" => Ok(OverloadMode::Shed),
            other => anyhow::bail!("unknown overload mode `{other}`"),
        }
    }
}

impl std::fmt::Display for OverloadMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            OverloadMode::BestEffort => "best-effort",
            OverloadMode::Shed => "shed",
        })
    }
}

/// Trim an over-SLO online batch for [`OverloadMode::Shed`]: drop the
/// longest-KV requests (most latency relief per shed request) until the
/// remainder fits `slo_bound`; at least one request is always kept.
/// Returns (kept, shed).
pub fn shed_online_overload(
    pm: &PerfModel,
    online: &[Candidate],
    slo_bound: f64,
) -> (Vec<Candidate>, Vec<RequestId>) {
    let mut kept: Vec<Candidate> = online.to_vec();
    kept.sort_unstable_by_key(|c| c.1); // ascending; shed from the tail
    let mut stats = BatchStats::new(
        kept.len(),
        kept.iter().map(|c| c.1).sum(),
    );
    let mut shed = Vec::new();
    while kept.len() > 1 && pm.decode_latency(stats) > slo_bound {
        let victim = kept.pop().expect("len > 1");
        stats = stats.without(victim.1);
        shed.push(victim.0);
    }
    (kept, shed)
}

/// Result of a selection.
#[derive(Debug, Clone, PartialEq)]
pub struct Selection {
    /// Offline requests admitted into this iteration's batch.
    pub offline: Vec<RequestId>,
    /// Aggregates of the full batch (online + admitted offline).
    pub stats: BatchStats,
    /// Predicted iteration latency.
    pub predicted_latency: f64,
    /// True if even the online-only batch exceeds the bound (best-effort
    /// mode decodes it anyway; the caller may alternatively shed load).
    pub online_over_slo: bool,
}

/// Algorithm 2. `online`/`offline` carry `(id, kv_len)`; `slo_bound` is the
/// TPOT bound S (already margin-adjusted by the caller if desired).
pub fn select_decode_batch(
    pm: &PerfModel,
    online: &[Candidate],
    offline: &[Candidate],
    slo_bound: f64,
    probes: usize,
    rng: &mut Pcg,
) -> Selection {
    // Line 1: all online requests are always included.
    let online_tokens: usize = online.iter().map(|c| c.1).sum();
    let mut stats = BatchStats::new(online.len(), online_tokens);
    let online_over_slo = !online.is_empty() && pm.decode_latency(stats) > slo_bound;

    let mut chosen: Vec<RequestId> = Vec::new();
    if offline.is_empty() {
        let predicted_latency = pm.decode_latency(stats);
        return Selection {
            offline: chosen,
            stats,
            predicted_latency,
            online_over_slo,
        };
    }

    // Lines 2-9: random probes over the offline set (up to K distinct).
    let k = probes.min(offline.len());
    let probe_idx = rng.sample_indices(offline.len(), k);
    let mut probed = vec![false; offline.len()];
    for &i in &probe_idx {
        probed[i] = true;
        let (id, kv) = offline[i];
        let trial = stats.with(kv);
        if pm.decode_latency(trial) <= slo_bound {
            stats = trial;
            chosen.push(id);
        }
        // else: discard r (this iteration).
    }

    // Lines 10-14: if untested candidates remain and we are still under the
    // bound, sort them ascending by length and binary-search the largest
    // admissible prefix.
    if pm.decode_latency(stats) <= slo_bound {
        let mut rest: Vec<Candidate> = offline
            .iter()
            .enumerate()
            .filter(|(i, _)| !probed[*i])
            .map(|(_, c)| *c)
            .collect();
        if !rest.is_empty() {
            rest.sort_unstable_by_key(|c| c.1);
            let lens: Vec<usize> = rest.iter().map(|c| c.1).collect();
            let sums = PrefixSums::of(&lens);
            let k =
                sums.max_prefix(stats, |b| pm.decode_latency(b) <= slo_bound);
            for c in &rest[..k] {
                chosen.push(c.0);
            }
            stats = sums.extend(stats, k);
        }
    }

    let predicted_latency = pm.decode_latency(stats);
    Selection {
        offline: chosen,
        stats,
        predicted_latency,
        online_over_slo,
    }
}

/// The ablation/baseline alternative: admit offline candidates greedily in
/// arrival order up to `cap` total batch size, with no latency prediction
/// (what `online priority` does).
pub fn select_decode_batch_capped(
    online: &[Candidate],
    offline: &[Candidate],
    cap: usize,
) -> Selection {
    let online_tokens: usize = online.iter().map(|c| c.1).sum();
    let mut stats = BatchStats::new(online.len(), online_tokens);
    let mut chosen = Vec::new();
    for &(id, kv) in offline {
        if stats.size >= cap {
            break;
        }
        stats = stats.with(kv);
        chosen.push(id);
    }
    Selection {
        offline: chosen,
        stats,
        predicted_latency: 0.0,
        online_over_slo: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareProfile, ModelSpec};

    fn pm() -> PerfModel {
        PerfModel::new(ModelSpec::qwen2_5_7b(), HardwareProfile::ascend_910c())
    }

    fn rng() -> Pcg {
        Pcg::seeded(1)
    }

    fn cands(lens: &[usize], base_id: u64) -> Vec<Candidate> {
        lens.iter()
            .enumerate()
            .map(|(i, &l)| (base_id + i as u64, l))
            .collect()
    }

    #[test]
    fn online_always_included() {
        let pm = pm();
        let online = cands(&[1000, 2000, 1500], 0);
        let sel = select_decode_batch(&pm, &online, &[], 0.1, 8, &mut rng());
        assert_eq!(sel.stats.size, 3);
        assert_eq!(sel.stats.total_kv_tokens, 4500);
        assert!(sel.offline.is_empty());
        assert!(!sel.online_over_slo);
    }

    #[test]
    fn respects_slo_bound() {
        let pm = pm();
        let online = cands(&[1000; 20], 0);
        let offline = cands(&[1500; 400], 100);
        let bound = 0.08;
        let sel = select_decode_batch(&pm, &online, &offline, bound, 8, &mut rng());
        assert!(
            sel.predicted_latency <= bound + 1e-12,
            "lat {} > bound",
            sel.predicted_latency
        );
        // And it admitted a useful number of offline requests.
        assert!(sel.offline.len() > 10, "admitted {}", sel.offline.len());
        // Adding one more of the shortest length would break the bound OR
        // everything was admitted.
        if sel.offline.len() < offline.len() {
            let with_one = sel.stats.with(1500);
            assert!(pm.decode_latency(with_one) > bound);
        }
    }

    #[test]
    fn admits_everything_when_loose() {
        let pm = pm();
        let online = cands(&[500; 4], 0);
        let offline = cands(&[700; 30], 100);
        let sel = select_decode_batch(&pm, &online, &offline, 10.0, 8, &mut rng());
        assert_eq!(sel.offline.len(), 30);
        assert_eq!(sel.stats.size, 34);
    }

    #[test]
    fn online_over_slo_flagged_but_decoded() {
        let pm = pm();
        // Enormous online batch that alone blows a tight bound.
        let online = cands(&[4000; 900], 0);
        let sel = select_decode_batch(&pm, &online, &cands(&[100; 5], 2000), 0.02, 4, &mut rng());
        assert!(sel.online_over_slo);
        assert_eq!(sel.stats.size, 900); // no offline admitted
        assert!(sel.offline.is_empty());
    }

    #[test]
    fn no_duplicate_admissions() {
        let pm = pm();
        let offline = cands(&[800; 120], 0);
        let sel = select_decode_batch(&pm, &[], &offline, 0.06, 16, &mut rng());
        let mut ids = sel.offline.clone();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), sel.offline.len(), "duplicate admission");
    }

    #[test]
    fn stats_match_choice() {
        // Property: returned stats equal online + chosen offline aggregates.
        let pm = pm();
        crate::testutil::forall(30, |r| {
            let n_on = r.below(10);
            let n_off = r.below(60);
            let online: Vec<Candidate> = (0..n_on)
                .map(|i| (i as u64, r.below(3000) + 1))
                .collect();
            let offline: Vec<Candidate> = (0..n_off)
                .map(|i| (1000 + i as u64, r.below(3000) + 1))
                .collect();
            let bound = 0.02 + r.f64() * 0.1;
            let sel = select_decode_batch(&pm, &online, &offline, bound, 8, r);
            let mut size = online.len();
            let mut toks: usize = online.iter().map(|c| c.1).sum();
            for id in &sel.offline {
                let c = offline.iter().find(|c| c.0 == *id).unwrap();
                size += 1;
                toks += c.1;
            }
            crate::prop_assert!(
                sel.stats == BatchStats::new(size, toks),
                "stats mismatch {:?} vs ({size},{toks})",
                sel.stats
            );
            // Predictor consistency.
            crate::prop_assert!(
                (sel.predicted_latency - pm.decode_latency(sel.stats)).abs() < 1e-12,
                "latency mismatch"
            );
            // SLO respected whenever online alone fits.
            if !sel.online_over_slo {
                crate::prop_assert!(
                    sel.predicted_latency <= bound + 1e-12,
                    "bound violated: {} > {bound}",
                    sel.predicted_latency
                );
            }
            Ok(())
        });
    }

    #[test]
    fn random_probes_reach_long_requests() {
        // Starvation avoidance: one very long offline request among many
        // short ones must be admitted in SOME iterations (when probed and
        // fitting), even though sorted admission would always leave it last.
        let pm = pm();
        let mut offline = cands(&[200; 40], 0);
        offline.push((999, 30_000)); // the long one
        let bound = 0.065;
        let mut seen_long = false;
        let mut r = Pcg::seeded(3);
        for _ in 0..60 {
            let sel = select_decode_batch(&pm, &[], &offline, bound, 8, &mut r);
            if sel.offline.contains(&999) {
                seen_long = true;
                break;
            }
        }
        assert!(seen_long, "long request starved across 60 iterations");
    }

    #[test]
    fn shed_mode_trims_to_bound() {
        let pm = pm();
        // A batch far over a tight bound.
        let online: Vec<Candidate> =
            (0..900).map(|i| (i as u64, 2000 + (i as usize % 7) * 500)).collect();
        let bound = 0.05;
        let over = {
            let toks: usize = online.iter().map(|c| c.1).sum();
            pm.decode_latency(BatchStats::new(online.len(), toks))
        };
        assert!(over > bound, "precondition");
        let (kept, shed) = shed_online_overload(&pm, &online, bound);
        assert_eq!(kept.len() + shed.len(), online.len());
        assert!(!kept.is_empty());
        let toks: usize = kept.iter().map(|c| c.1).sum();
        assert!(pm.decode_latency(BatchStats::new(kept.len(), toks)) <= bound);
        // Shed requests are the longest ones.
        let min_shed = shed
            .iter()
            .map(|id| online.iter().find(|c| c.0 == *id).unwrap().1)
            .min()
            .unwrap();
        assert!(kept.iter().all(|c| c.1 <= min_shed));
    }

    #[test]
    fn shed_mode_keeps_fitting_batch_intact() {
        let pm = pm();
        let online: Vec<Candidate> = (0..4).map(|i| (i as u64, 500)).collect();
        let (kept, shed) = shed_online_overload(&pm, &online, 1.0);
        assert_eq!(kept.len(), 4);
        assert!(shed.is_empty());
    }

    #[test]
    fn shed_mode_always_keeps_one() {
        let pm = pm();
        let online: Vec<Candidate> = (0..10).map(|i| (i as u64, 4000)).collect();
        // Bound below even a single request's latency.
        let (kept, shed) = shed_online_overload(&pm, &online, 1e-6);
        assert_eq!(kept.len(), 1);
        assert_eq!(shed.len(), 9);
    }

    #[test]
    fn overload_mode_roundtrip() {
        for m in [OverloadMode::BestEffort, OverloadMode::Shed] {
            assert_eq!(m.to_string().parse::<OverloadMode>().unwrap(), m);
        }
        assert!("panic".parse::<OverloadMode>().is_err());
    }

    #[test]
    fn capped_baseline() {
        let online = cands(&[100; 3], 0);
        let offline = cands(&[100; 50], 10);
        let sel = select_decode_batch_capped(&online, &offline, 10);
        assert_eq!(sel.stats.size, 10);
        assert_eq!(sel.offline.len(), 7);
        // Cap below online size admits nothing offline.
        let sel = select_decode_batch_capped(&online, &offline, 2);
        assert!(sel.offline.is_empty());
    }
}
