//! The three compared systems (paper §5.1.4), as one policy switch.
//!
//! All three run on the same instances, queues and perf model; the policy
//! only toggles which scheduling mechanisms are active — exactly how the
//! paper constructs its baselines on top of xLLM.

/// Scheduling policy under evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// `base P/D`: standard P/D disaggregation; offline requests are treated
    /// as ordinary online requests (vLLM/SGLang/DistServe applied naively).
    BasePd,
    /// `online priority`: HyGen/Echo-style online/offline awareness ported
    /// onto P/D disaggregation — idle-only offline scheduling, fixed decode
    /// batch cap, preemption on online traffic.
    OnlinePriority,
    /// OOCO: latency-constraint disaggregation + bottleneck-based
    /// scheduling (this paper).
    Ooco,
}

impl std::str::FromStr for Policy {
    type Err = anyhow::Error;

    fn from_str(name: &str) -> anyhow::Result<Policy> {
        match name {
            "base-pd" | "base_pd" | "basepd" => Ok(Policy::BasePd),
            "online-priority" | "online_priority" => Ok(Policy::OnlinePriority),
            "ooco" => Ok(Policy::Ooco),
            other => anyhow::bail!("unknown policy `{other}`"),
        }
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl Policy {
    pub fn name(self) -> &'static str {
        match self {
            Policy::BasePd => "base-pd",
            Policy::OnlinePriority => "online-priority",
            Policy::Ooco => "ooco",
        }
    }

    pub fn all() -> [Policy; 3] {
        [Policy::BasePd, Policy::OnlinePriority, Policy::Ooco]
    }

    // ------------------------------------------------ mechanism switches

    /// Does online work preempt running offline prefill steps?
    pub fn preempts_offline_prefill(self) -> bool {
        !matches!(self, Policy::BasePd)
    }

    /// Are offline requests only prefilled when no online work is waiting?
    pub fn offline_idle_only(self) -> bool {
        !matches!(self, Policy::BasePd)
    }

    /// May offline requests decode on latency-relaxed instances?
    /// (The latency-constraint disaggregation — OOCO only.)
    pub fn offline_decode_on_relaxed(self) -> bool {
        matches!(self, Policy::Ooco)
    }

    /// Is the strict-node decode batch chosen by the SLO-aware predictor
    /// (Algorithm 2) instead of a fixed heuristic?
    pub fn slo_aware_mix_decode(self) -> bool {
        matches!(self, Policy::Ooco)
    }

    /// Does the strict node pull offline decodes from relaxed nodes
    /// (Algorithm 1)?
    pub fn migration_enabled(self) -> bool {
        matches!(self, Policy::Ooco)
    }

    /// Is the offline-gating cost model active on relaxed nodes?
    pub fn gating_enabled(self) -> bool {
        matches!(self, Policy::Ooco)
    }

    /// Is eviction victim selection bottleneck-aware (vs oldest-first)?
    pub fn bottleneck_aware_eviction(self) -> bool {
        matches!(self, Policy::Ooco)
    }

    /// Fixed decode-batch cap applied to offline mix-in (`online priority`'s
    /// safeguard). `None` = no static cap.
    pub fn static_offline_decode_cap(self, cap: usize) -> Option<usize> {
        match self {
            Policy::OnlinePriority => Some(cap),
            _ => None,
        }
    }
}

/// Ablation toggles (used by `bench_ablation`): start from OOCO and switch
/// individual mechanisms off to quantify their contribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ablation {
    pub mix_decode: bool,
    pub migration: bool,
    pub gating: bool,
    pub bottleneck_eviction: bool,
}

impl Default for Ablation {
    fn default() -> Self {
        Ablation {
            mix_decode: true,
            migration: true,
            gating: true,
            bottleneck_eviction: true,
        }
    }
}

impl std::str::FromStr for Ablation {
    type Err = anyhow::Error;

    /// Parse a named ablation preset (the `bench_ablation` vocabulary).
    fn from_str(name: &str) -> anyhow::Result<Ablation> {
        match name {
            "full" => Ok(Ablation::full()),
            "no-mix-decode" | "no_mix_decode" => {
                Ok(Ablation::without_mix_decode())
            }
            "no-migration" | "no_migration" => {
                Ok(Ablation::without_migration())
            }
            "no-gating" | "no_gating" => Ok(Ablation::without_gating()),
            "no-bottleneck-eviction" | "no_bottleneck_eviction" => {
                Ok(Ablation::without_bottleneck_eviction())
            }
            // The `custom(+a,-b,...)` form produced by `Display` for
            // combinations without a preset name — Display/FromStr
            // roundtrip for every value, like Policy and OverloadMode.
            other => {
                let Some(body) = other
                    .strip_prefix("custom(")
                    .and_then(|s| s.strip_suffix(')'))
                else {
                    anyhow::bail!("unknown ablation preset `{other}`");
                };
                let mut a = Ablation::full();
                for tok in body.split(',') {
                    let tok = tok.trim();
                    let (on, name) = if let Some(n) = tok.strip_prefix('+') {
                        (true, n)
                    } else if let Some(n) = tok.strip_prefix('-') {
                        (false, n)
                    } else {
                        anyhow::bail!("bad ablation toggle `{tok}`");
                    };
                    match name {
                        "mix_decode" => a.mix_decode = on,
                        "migration" => a.migration = on,
                        "gating" => a.gating = on,
                        "bottleneck_eviction" => a.bottleneck_eviction = on,
                        _ => anyhow::bail!("unknown ablation toggle `{name}`"),
                    }
                }
                Ok(a)
            }
        }
    }
}

impl std::fmt::Display for Ablation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

impl Ablation {
    pub fn full() -> Self {
        Self::default()
    }

    /// Preset name when this combination matches one; a `+`/`-` toggle list
    /// otherwise (e.g. `custom(-mix_decode,-gating)`).
    pub fn name(&self) -> String {
        match (
            self.mix_decode,
            self.migration,
            self.gating,
            self.bottleneck_eviction,
        ) {
            (true, true, true, true) => "full".into(),
            (false, true, true, true) => "no-mix-decode".into(),
            (true, false, true, true) => "no-migration".into(),
            (true, true, false, true) => "no-gating".into(),
            (true, true, true, false) => "no-bottleneck-eviction".into(),
            _ => {
                let flag = |on: bool| if on { '+' } else { '-' };
                format!(
                    "custom({}mix_decode,{}migration,{}gating,{}bottleneck_eviction)",
                    flag(self.mix_decode),
                    flag(self.migration),
                    flag(self.gating),
                    flag(self.bottleneck_eviction)
                )
            }
        }
    }

    pub fn without_mix_decode() -> Self {
        Ablation {
            mix_decode: false,
            ..Self::default()
        }
    }

    pub fn without_migration() -> Self {
        Ablation {
            migration: false,
            ..Self::default()
        }
    }

    pub fn without_gating() -> Self {
        Ablation {
            gating: false,
            ..Self::default()
        }
    }

    pub fn without_bottleneck_eviction() -> Self {
        Ablation {
            bottleneck_eviction: false,
            ..Self::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for p in Policy::all() {
            assert_eq!(p.name().parse::<Policy>().unwrap(), p);
            assert_eq!(p.to_string(), p.name());
        }
        assert!("magic".parse::<Policy>().is_err());
    }

    #[test]
    fn ablation_presets_roundtrip() {
        for name in [
            "full",
            "no-mix-decode",
            "no-migration",
            "no-gating",
            "no-bottleneck-eviction",
        ] {
            let a: Ablation = name.parse().unwrap();
            assert_eq!(a.name(), name);
            assert_eq!(a.to_string(), name);
        }
        assert!("no-everything".parse::<Ablation>().is_err());
        assert!("custom(+mix_decode,?gating)".parse::<Ablation>().is_err());
        // Unnamed combinations render as a toggle list that roundtrips too.
        let mut odd = Ablation::full();
        odd.mix_decode = false;
        odd.gating = false;
        assert!(odd.name().starts_with("custom("));
        assert_eq!(odd.to_string().parse::<Ablation>().unwrap(), odd);
    }

    #[test]
    fn mechanism_matrix() {
        // base P/D: nothing online/offline-aware.
        let p = Policy::BasePd;
        assert!(!p.preempts_offline_prefill());
        assert!(!p.offline_idle_only());
        assert!(!p.offline_decode_on_relaxed());
        assert!(!p.slo_aware_mix_decode());
        assert!(p.static_offline_decode_cap(96).is_none());

        // online priority: protection without latency-constraint flexibility.
        let p = Policy::OnlinePriority;
        assert!(p.preempts_offline_prefill());
        assert!(p.offline_idle_only());
        assert!(!p.offline_decode_on_relaxed());
        assert!(!p.migration_enabled());
        assert_eq!(p.static_offline_decode_cap(96), Some(96));

        // OOCO: everything on.
        let p = Policy::Ooco;
        assert!(p.offline_decode_on_relaxed());
        assert!(p.slo_aware_mix_decode());
        assert!(p.migration_enabled());
        assert!(p.gating_enabled());
        assert!(p.bottleneck_aware_eviction());
        assert!(p.static_offline_decode_cap(96).is_none());
    }

    #[test]
    fn ablations() {
        assert!(Ablation::full().mix_decode);
        assert!(!Ablation::without_migration().migration);
        assert!(Ablation::without_migration().mix_decode);
        assert!(!Ablation::without_gating().gating);
        assert!(!Ablation::without_bottleneck_eviction().bottleneck_eviction);
    }
}
