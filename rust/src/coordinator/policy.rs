//! The three compared systems (paper §5.1.4), as one policy switch.
//!
//! All three run on the same instances, queues and perf model; the policy
//! only toggles which scheduling mechanisms are active — exactly how the
//! paper constructs its baselines on top of xLLM.

/// Scheduling policy under evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// `base P/D`: standard P/D disaggregation; offline requests are treated
    /// as ordinary online requests (vLLM/SGLang/DistServe applied naively).
    BasePd,
    /// `online priority`: HyGen/Echo-style online/offline awareness ported
    /// onto P/D disaggregation — idle-only offline scheduling, fixed decode
    /// batch cap, preemption on online traffic.
    OnlinePriority,
    /// OOCO: latency-constraint disaggregation + bottleneck-based
    /// scheduling (this paper).
    Ooco,
}

impl Policy {
    pub fn by_name(name: &str) -> anyhow::Result<Policy> {
        match name {
            "base-pd" | "base_pd" | "basepd" => Ok(Policy::BasePd),
            "online-priority" | "online_priority" => Ok(Policy::OnlinePriority),
            "ooco" => Ok(Policy::Ooco),
            other => anyhow::bail!("unknown policy `{other}`"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Policy::BasePd => "base-pd",
            Policy::OnlinePriority => "online-priority",
            Policy::Ooco => "ooco",
        }
    }

    pub fn all() -> [Policy; 3] {
        [Policy::BasePd, Policy::OnlinePriority, Policy::Ooco]
    }

    // ------------------------------------------------ mechanism switches

    /// Does online work preempt running offline prefill steps?
    pub fn preempts_offline_prefill(self) -> bool {
        !matches!(self, Policy::BasePd)
    }

    /// Are offline requests only prefilled when no online work is waiting?
    pub fn offline_idle_only(self) -> bool {
        !matches!(self, Policy::BasePd)
    }

    /// May offline requests decode on latency-relaxed instances?
    /// (The latency-constraint disaggregation — OOCO only.)
    pub fn offline_decode_on_relaxed(self) -> bool {
        matches!(self, Policy::Ooco)
    }

    /// Is the strict-node decode batch chosen by the SLO-aware predictor
    /// (Algorithm 2) instead of a fixed heuristic?
    pub fn slo_aware_mix_decode(self) -> bool {
        matches!(self, Policy::Ooco)
    }

    /// Does the strict node pull offline decodes from relaxed nodes
    /// (Algorithm 1)?
    pub fn migration_enabled(self) -> bool {
        matches!(self, Policy::Ooco)
    }

    /// Is the offline-gating cost model active on relaxed nodes?
    pub fn gating_enabled(self) -> bool {
        matches!(self, Policy::Ooco)
    }

    /// Is eviction victim selection bottleneck-aware (vs oldest-first)?
    pub fn bottleneck_aware_eviction(self) -> bool {
        matches!(self, Policy::Ooco)
    }

    /// Fixed decode-batch cap applied to offline mix-in (`online priority`'s
    /// safeguard). `None` = no static cap.
    pub fn static_offline_decode_cap(self, cap: usize) -> Option<usize> {
        match self {
            Policy::OnlinePriority => Some(cap),
            _ => None,
        }
    }
}

/// Ablation toggles (used by `bench_ablation`): start from OOCO and switch
/// individual mechanisms off to quantify their contribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ablation {
    pub mix_decode: bool,
    pub migration: bool,
    pub gating: bool,
    pub bottleneck_eviction: bool,
}

impl Default for Ablation {
    fn default() -> Self {
        Ablation {
            mix_decode: true,
            migration: true,
            gating: true,
            bottleneck_eviction: true,
        }
    }
}

impl Ablation {
    pub fn full() -> Self {
        Self::default()
    }

    pub fn without_mix_decode() -> Self {
        Ablation {
            mix_decode: false,
            ..Self::default()
        }
    }

    pub fn without_migration() -> Self {
        Ablation {
            migration: false,
            ..Self::default()
        }
    }

    pub fn without_gating() -> Self {
        Ablation {
            gating: false,
            ..Self::default()
        }
    }

    pub fn without_bottleneck_eviction() -> Self {
        Ablation {
            bottleneck_eviction: false,
            ..Self::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for p in Policy::all() {
            assert_eq!(Policy::by_name(p.name()).unwrap(), p);
        }
        assert!(Policy::by_name("magic").is_err());
    }

    #[test]
    fn mechanism_matrix() {
        // base P/D: nothing online/offline-aware.
        let p = Policy::BasePd;
        assert!(!p.preempts_offline_prefill());
        assert!(!p.offline_idle_only());
        assert!(!p.offline_decode_on_relaxed());
        assert!(!p.slo_aware_mix_decode());
        assert!(p.static_offline_decode_cap(96).is_none());

        // online priority: protection without latency-constraint flexibility.
        let p = Policy::OnlinePriority;
        assert!(p.preempts_offline_prefill());
        assert!(p.offline_idle_only());
        assert!(!p.offline_decode_on_relaxed());
        assert!(!p.migration_enabled());
        assert_eq!(p.static_offline_decode_cap(96), Some(96));

        // OOCO: everything on.
        let p = Policy::Ooco;
        assert!(p.offline_decode_on_relaxed());
        assert!(p.slo_aware_mix_decode());
        assert!(p.migration_enabled());
        assert!(p.gating_enabled());
        assert!(p.bottleneck_aware_eviction());
        assert!(p.static_offline_decode_cap(96).is_none());
    }

    #[test]
    fn ablations() {
        assert!(Ablation::full().mix_decode);
        assert!(!Ablation::without_migration().migration);
        assert!(Ablation::without_migration().mix_decode);
        assert!(!Ablation::without_gating().gating);
        assert!(!Ablation::without_bottleneck_eviction().bottleneck_eviction);
    }
}
