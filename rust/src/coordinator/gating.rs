//! Offline Request Gating (§3.4.2).
//!
//! When a latency-relaxed node is idle (no online prefill waiting) it can
//! either decode its resident offline requests or prefill *new* offline
//! requests to enlarge the future decode batch. Prefilling is worthwhile
//! only if the effective per-token latency reduction from the larger batch
//! exceeds the expected recompute cost from potential eviction during a
//! future online burst:
//!
//! `admit  <=>  benefit >= ratio * eviction_prob * recompute_cost`
//!
//! where `benefit = remaining_output_tokens * (L(n)/n - L(n+1)/(n+1))`
//! (decode time saved for the whole pool by amortizing over one more
//! request) and `recompute_cost = prefill_latency(prompt)`.

use crate::config::SchedulerParams;
use crate::perfmodel::{BatchStats, PerfModel};

/// Decision input for one gating check on a relaxed node.
#[derive(Debug, Clone, Copy)]
pub struct GatingInput {
    /// Current offline decode pool on this node.
    pub pool: BatchStats,
    /// Prompt length of the candidate offline request.
    pub candidate_prompt: usize,
    /// Expected output length of the candidate (trace metadata / estimate).
    pub candidate_output: usize,
    /// Mean remaining output tokens per pooled request (benefit horizon).
    pub pool_mean_remaining: f64,
    /// Free KV tokens on the node after reserving online-prefill headroom.
    pub free_kv_tokens: usize,
}

/// Should the node prefill this offline request now?
pub fn should_prefill_offline(
    pm: &PerfModel,
    input: &GatingInput,
    params: &SchedulerParams,
) -> bool {
    // Hard constraint: the candidate's KV must fit in the reserved-free space.
    if input.candidate_prompt + 1 > input.free_kv_tokens {
        return false;
    }

    // An empty pool always benefits from work (nothing to amortize against).
    if input.pool.is_empty() {
        return true;
    }

    // Per-token decode latency now vs with the candidate added.
    let n = input.pool.size as f64;
    let now = pm.decode_latency(input.pool) / n;
    let with = input
        .pool
        .with(input.candidate_prompt + input.candidate_output / 2);
    let later = pm.decode_latency(with) / (n + 1.0);
    let per_token_gain = (now - later).max(0.0);

    // Benefit accrues over the pool's remaining tokens plus the candidate's.
    let horizon = input.pool_mean_remaining * n + input.candidate_output as f64;
    let benefit = per_token_gain * horizon;

    let recompute_cost = pm.prefill_latency(input.candidate_prompt);
    let cost = params.eviction_prob * recompute_cost;

    benefit >= params.gating_benefit_ratio * cost
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareProfile, ModelSpec};

    fn pm() -> PerfModel {
        PerfModel::new(ModelSpec::qwen2_5_7b(), HardwareProfile::ascend_910c())
    }

    fn input(pool: BatchStats, free: usize) -> GatingInput {
        GatingInput {
            pool,
            candidate_prompt: 1200,
            candidate_output: 600,
            pool_mean_remaining: 300.0,
            free_kv_tokens: free,
        }
    }

    #[test]
    fn empty_pool_admits() {
        let pm = pm();
        let inp = input(BatchStats::empty(), 100_000);
        assert!(should_prefill_offline(&pm, &inp, &SchedulerParams::default()));
    }

    #[test]
    fn no_space_rejects() {
        let pm = pm();
        let inp = input(BatchStats::empty(), 500); // prompt 1200 won't fit
        assert!(!should_prefill_offline(&pm, &inp, &SchedulerParams::default()));
    }

    #[test]
    fn small_pool_admits_large_pool_rejects() {
        let pm = pm();
        let params = SchedulerParams::default();
        // Small pool: big amortization gain per added request.
        let small = input(BatchStats::new(3, 3 * 1500), 400_000);
        assert!(should_prefill_offline(&pm, &small, &params));
        // Far beyond compute saturation: marginal gain ~0, eviction risk
        // dominates.
        let sat = pm.bs_sat();
        let big = input(BatchStats::new(sat * 3, sat * 3 * 1500), 400_000);
        assert!(!should_prefill_offline(&pm, &big, &params));
    }

    #[test]
    fn higher_eviction_prob_rejects_earlier() {
        let pm = pm();
        // Find a pool size where the default admits...
        let mut params = SchedulerParams::default();
        params.eviction_prob = 0.05;
        let sat = pm.bs_sat();
        let pool = BatchStats::new(sat / 2, sat / 2 * 1500);
        let inp = input(pool, 400_000);
        let admits_low = should_prefill_offline(&pm, &inp, &params);
        // ...and a near-certain eviction rejects.
        params.eviction_prob = 50.0; // exaggerated to force the flip
        let admits_high = should_prefill_offline(&pm, &inp, &params);
        assert!(admits_low || !admits_high); // monotone in eviction_prob
        assert!(!admits_high, "near-certain eviction must reject");
    }

    #[test]
    fn benefit_ratio_knob_monotone() {
        let pm = pm();
        let sat = pm.bs_sat();
        let inp = input(BatchStats::new(sat / 2, sat / 2 * 1200), 400_000);
        let mut admit_count = 0;
        for ratio in [0.1, 1.0, 10.0, 1000.0] {
            let mut p = SchedulerParams::default();
            p.gating_benefit_ratio = ratio;
            if should_prefill_offline(&pm, &inp, &p) {
                admit_count += 1;
            } else {
                break; // once rejected, higher ratios must also reject
            }
        }
        assert!(admit_count >= 1);
    }
}
