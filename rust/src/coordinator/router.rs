//! Request-level routing across instances — the `xllm-service` analog.
//!
//! Chooses which latency-relaxed instance prefills a request and which
//! latency-strict instance receives its decode, by least outstanding load.
//! Online-to-strict dispatch is a *push* (immediately after prefill, to
//! start decoding ASAP — §3.4.3); offline migration is the strict nodes'
//! *pull*, implemented in [`super::migration`].

/// Tracks per-instance outstanding load for balanced dispatch.
#[derive(Debug, Clone)]
pub struct Router {
    /// Outstanding prefill tokens queued per relaxed instance.
    relaxed_load: Vec<u64>,
    /// Resident decode KV tokens per strict instance.
    strict_load: Vec<u64>,
}

impl Router {
    pub fn new(relaxed: usize, strict: usize) -> Self {
        assert!(relaxed > 0 && strict > 0);
        Router {
            relaxed_load: vec![0; relaxed],
            strict_load: vec![0; strict],
        }
    }

    pub fn relaxed_count(&self) -> usize {
        self.relaxed_load.len()
    }

    pub fn strict_count(&self) -> usize {
        self.strict_load.len()
    }

    /// Pick the relaxed instance for a prefill of `tokens`, recording load.
    pub fn route_prefill(&mut self, tokens: usize) -> usize {
        let idx = argmin(&self.relaxed_load);
        self.relaxed_load[idx] += tokens as u64;
        idx
    }

    /// Prefill finished: discharge its queued load.
    pub fn prefill_done(&mut self, instance: usize, tokens: usize) {
        let l = &mut self.relaxed_load[instance];
        *l = l.saturating_sub(tokens as u64);
    }

    /// Pick the strict instance for a decode of `kv_tokens`, recording load.
    pub fn route_decode(&mut self, kv_tokens: usize) -> usize {
        let idx = argmin(&self.strict_load);
        self.strict_load[idx] += kv_tokens as u64;
        idx
    }

    /// Decode resident left (finished / evicted / migrated away).
    pub fn decode_done(&mut self, instance: usize, kv_tokens: usize) {
        let l = &mut self.strict_load[instance];
        *l = l.saturating_sub(kv_tokens as u64);
    }

    /// Decode resident grew by one token (KV growth during decoding).
    pub fn decode_grow(&mut self, instance: usize, tokens: usize) {
        self.strict_load[instance] += tokens as u64;
    }
}

fn argmin(v: &[u64]) -> usize {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x < v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balances_prefill_load() {
        let mut r = Router::new(3, 1);
        let a = r.route_prefill(100);
        let b = r.route_prefill(100);
        let c = r.route_prefill(100);
        // Three equal requests land on three different instances.
        let mut seen = vec![a, b, c];
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 3);
        // Fourth goes wherever, but after discharging instance `a` it is
        // the least loaded again.
        r.prefill_done(a, 100);
        assert_eq!(r.route_prefill(10), a);
    }

    #[test]
    fn prefers_least_kv_strict() {
        let mut r = Router::new(1, 2);
        let a = r.route_decode(5000);
        let b = r.route_decode(100);
        assert_ne!(a, b);
        // b has less load, next goes to b again.
        assert_eq!(r.route_decode(100), b);
        r.decode_done(a, 5000);
        assert_eq!(r.route_decode(1), a);
    }

    #[test]
    fn growth_and_saturating_discharge() {
        let mut r = Router::new(1, 1);
        let i = r.route_decode(10);
        r.decode_grow(i, 5);
        r.decode_done(i, 100); // over-discharge clamps to zero
        assert_eq!(r.route_decode(1), i);
    }

    #[test]
    #[should_panic]
    fn zero_instances_panics() {
        Router::new(0, 1);
    }
}
