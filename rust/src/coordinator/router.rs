//! Request-level routing across instances — the `xllm-service` analog.
//!
//! Chooses which latency-relaxed instance prefills a request and which
//! latency-strict instance receives its decode, by least outstanding load.
//! Online-to-strict dispatch is a *push* (immediately after prefill, to
//! start decoding ASAP — §3.4.3); offline migration is the strict nodes'
//! *pull*, implemented in [`super::migration`].
//!
//! The elastic pool manager (DESIGN.md §3.6) resizes the pools at runtime:
//! a draining instance is excluded from new-work routing, and a completed
//! role flip moves one load slot from the tail of one pool to the other.

/// Tracks per-instance outstanding load for balanced dispatch.
#[derive(Debug, Clone)]
pub struct Router {
    /// Outstanding prefill tokens queued per relaxed instance.
    relaxed_load: Vec<u64>,
    /// Resident decode KV tokens per strict instance.
    strict_load: Vec<u64>,
    /// Relaxed instance currently draining (excluded from `route_prefill`).
    drain_relaxed: Option<usize>,
    /// Strict instance currently draining (excluded from `route_decode`).
    drain_strict: Option<usize>,
    /// Crashed relaxed instances (fleet fault model): hard-excluded from
    /// routing — unlike the drain slot, several may be down at once and a
    /// down instance is never a fallback target.
    down_relaxed: Vec<bool>,
    /// Crashed strict instances.
    down_strict: Vec<bool>,
}

impl Router {
    pub fn new(relaxed: usize, strict: usize) -> Self {
        assert!(relaxed > 0 && strict > 0);
        Router {
            relaxed_load: vec![0; relaxed],
            strict_load: vec![0; strict],
            drain_relaxed: None,
            drain_strict: None,
            down_relaxed: vec![false; relaxed],
            down_strict: vec![false; strict],
        }
    }

    pub fn relaxed_count(&self) -> usize {
        self.relaxed_load.len()
    }

    pub fn strict_count(&self) -> usize {
        self.strict_load.len()
    }

    /// Exclude (or re-include, with `None`) a relaxed instance from
    /// new-prefill routing while the pool manager drains it.
    pub fn set_drain_relaxed(&mut self, idx: Option<usize>) {
        self.drain_relaxed = idx;
    }

    /// Exclude (or re-include) a strict instance from decode routing.
    pub fn set_drain_strict(&mut self, idx: Option<usize>) {
        self.drain_strict = idx;
    }

    /// Mark a relaxed instance crashed (`true`) or recovered (`false`).
    /// A crashed instance also sheds its phantom load: nothing routed to
    /// it survives the crash, so the slot restarts empty on recovery.
    pub fn set_down_relaxed(&mut self, idx: usize, down: bool) {
        self.down_relaxed[idx] = down;
        if down {
            self.relaxed_load[idx] = 0;
        }
    }

    /// Mark a strict instance crashed or recovered.
    pub fn set_down_strict(&mut self, idx: usize, down: bool) {
        self.down_strict[idx] = down;
        if down {
            self.strict_load[idx] = 0;
        }
    }

    /// Any live (non-crashed) relaxed instance left?
    pub fn any_relaxed_up(&self) -> bool {
        self.down_relaxed.iter().any(|&d| !d)
    }

    /// Any live strict instance left?
    pub fn any_strict_up(&self) -> bool {
        self.down_strict.iter().any(|&d| !d)
    }

    /// Role flip relaxed→strict: retire the tail relaxed load slot and open
    /// a fresh strict one. The flipped instance carries no load (drained).
    pub fn flip_relaxed_to_strict(&mut self) {
        assert!(self.relaxed_load.len() > 1, "last relaxed instance");
        assert!(!self.down_relaxed.pop().unwrap(), "flip of a down instance");
        self.relaxed_load.pop();
        self.strict_load.push(0);
        self.down_strict.push(false);
        self.drain_relaxed = None;
    }

    /// Role flip strict→relaxed: retire the tail strict load slot and open
    /// a fresh relaxed one.
    pub fn flip_strict_to_relaxed(&mut self) {
        assert!(self.strict_load.len() > 1, "last strict instance");
        assert!(!self.down_strict.pop().unwrap(), "flip of a down instance");
        self.strict_load.pop();
        self.relaxed_load.push(0);
        self.down_relaxed.push(false);
        self.drain_strict = None;
    }

    /// Pick the relaxed instance for a prefill of `tokens`, recording load.
    pub fn route_prefill(&mut self, tokens: usize) -> usize {
        let idx =
            argmin_excl(&self.relaxed_load, self.drain_relaxed, &self.down_relaxed);
        self.relaxed_load[idx] += tokens as u64;
        idx
    }

    /// Prefill finished: discharge its queued load.
    pub fn prefill_done(&mut self, instance: usize, tokens: usize) {
        let l = &mut self.relaxed_load[instance];
        *l = l.saturating_sub(tokens as u64);
    }

    /// Pick the strict instance for a decode of `kv_tokens`, recording load.
    pub fn route_decode(&mut self, kv_tokens: usize) -> usize {
        let idx =
            argmin_excl(&self.strict_load, self.drain_strict, &self.down_strict);
        self.strict_load[idx] += kv_tokens as u64;
        idx
    }

    /// Decode resident left (finished / evicted / migrated away).
    pub fn decode_done(&mut self, instance: usize, kv_tokens: usize) {
        let l = &mut self.strict_load[instance];
        *l = l.saturating_sub(kv_tokens as u64);
    }

    /// Decode resident grew by one token (KV growth during decoding).
    pub fn decode_grow(&mut self, instance: usize, tokens: usize) {
        self.strict_load[instance] += tokens as u64;
    }
}

/// Least-loaded index, skipping `excl` unless it is the only live
/// instance, and never choosing a crashed (`down[i]`) instance. The last
/// live instance is always routable — crashing the final instance of a
/// pool is refused upstream (fleet fault injection skips it).
fn argmin_excl(v: &[u64], excl: Option<usize>, down: &[bool]) -> usize {
    let live = down.iter().filter(|&&d| !d).count();
    let mut best: Option<usize> = None;
    for (i, &x) in v.iter().enumerate() {
        if down[i] {
            continue;
        }
        if Some(i) == excl && live > 1 {
            continue;
        }
        match best {
            Some(b) if x >= v[b] => {}
            _ => best = Some(i),
        }
    }
    best.expect("at least one live instance")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balances_prefill_load() {
        let mut r = Router::new(3, 1);
        let a = r.route_prefill(100);
        let b = r.route_prefill(100);
        let c = r.route_prefill(100);
        // Three equal requests land on three different instances.
        let mut seen = vec![a, b, c];
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 3);
        // Fourth goes wherever, but after discharging instance `a` it is
        // the least loaded again.
        r.prefill_done(a, 100);
        assert_eq!(r.route_prefill(10), a);
    }

    #[test]
    fn prefers_least_kv_strict() {
        let mut r = Router::new(1, 2);
        let a = r.route_decode(5000);
        let b = r.route_decode(100);
        assert_ne!(a, b);
        // b has less load, next goes to b again.
        assert_eq!(r.route_decode(100), b);
        r.decode_done(a, 5000);
        assert_eq!(r.route_decode(1), a);
    }

    #[test]
    fn growth_and_saturating_discharge() {
        let mut r = Router::new(1, 1);
        let i = r.route_decode(10);
        r.decode_grow(i, 5);
        r.decode_done(i, 100); // over-discharge clamps to zero
        assert_eq!(r.route_decode(1), i);
    }

    #[test]
    fn draining_instance_is_skipped() {
        let mut r = Router::new(2, 2);
        r.set_drain_relaxed(Some(0));
        for _ in 0..4 {
            assert_eq!(r.route_prefill(10), 1);
        }
        r.set_drain_relaxed(None);
        assert_eq!(r.route_prefill(1), 0); // load 0 < 40, included again
        r.set_drain_strict(Some(1));
        for _ in 0..4 {
            assert_eq!(r.route_decode(10), 0);
        }
    }

    #[test]
    fn sole_instance_still_routes_despite_drain_mark() {
        let mut r = Router::new(1, 1);
        r.set_drain_relaxed(Some(0));
        r.set_drain_strict(Some(0));
        assert_eq!(r.route_prefill(1), 0);
        assert_eq!(r.route_decode(1), 0);
    }

    #[test]
    fn flips_move_tail_slots() {
        let mut r = Router::new(2, 1);
        r.set_drain_relaxed(Some(1));
        r.flip_relaxed_to_strict();
        assert_eq!(r.relaxed_count(), 1);
        assert_eq!(r.strict_count(), 2);
        // Drain mark cleared; fresh strict slot starts empty and wins.
        r.route_decode(100); // instance 0
        assert_eq!(r.route_decode(1), 1);
        r.flip_strict_to_relaxed();
        assert_eq!(r.relaxed_count(), 2);
        assert_eq!(r.strict_count(), 1);
    }

    #[test]
    fn down_instances_are_hard_excluded() {
        let mut r = Router::new(3, 2);
        r.route_prefill(100); // load instance 0
        r.set_down_relaxed(1, true);
        r.set_down_relaxed(2, true);
        // Both lighter instances are down — routing must fall back to 0.
        for _ in 0..3 {
            assert_eq!(r.route_prefill(10), 0);
        }
        r.set_down_relaxed(1, false);
        assert_eq!(r.route_prefill(1), 1); // recovered slot restarts empty
        // Down beats drain: a drained-but-live instance is still the
        // fallback when every other instance crashed.
        r.set_down_strict(0, true);
        r.set_drain_strict(Some(1));
        assert_eq!(r.route_decode(10), 1);
        assert!(r.any_strict_up());
        r.set_down_strict(1, true);
        assert!(!r.any_strict_up());
    }

    #[test]
    fn down_clears_phantom_load() {
        let mut r = Router::new(2, 1);
        let i = r.route_prefill(1000);
        r.set_down_relaxed(i, true);
        r.set_down_relaxed(i, false);
        // Crash shed the 1000-token load; the slot competes as empty.
        assert_eq!(r.route_prefill(1), i);
    }

    #[test]
    #[should_panic]
    fn zero_instances_panics() {
        Router::new(0, 1);
    }

    #[test]
    #[should_panic]
    fn flip_of_last_strict_panics() {
        Router::new(1, 1).flip_strict_to_relaxed();
    }
}
