//! Paged KV-cache manager (the PagedAttention-style memory substrate).
//!
//! Tracks block-granular allocations per request on one instance. The
//! simulator uses it for capacity accounting and eviction decisions; the
//! real engine uses it to bound admission on the tiny model. A free-list
//! allocator keeps alloc/free O(blocks) with zero steady-state heap churn
//! (hot-path requirement: every decode iteration may grow each request by
//! one token).

use std::collections::HashMap;

use crate::request::RequestId;

/// Block-granular paged allocator for one instance's KV memory.
#[derive(Debug)]
pub struct KvManager {
    /// Tokens per block (vLLM-style page size).
    block_tokens: usize,
    /// Total blocks in the pool.
    total_blocks: usize,
    /// Free block indices (LIFO for locality).
    free: Vec<u32>,
    /// Per-request allocation: block list + exact token count.
    allocs: HashMap<RequestId, Alloc>,
}

#[derive(Debug, Clone)]
struct Alloc {
    blocks: Vec<u32>,
    tokens: usize,
}

/// Why an allocation failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, thiserror::Error)]
pub enum KvError {
    #[error("out of KV blocks")]
    OutOfMemory,
    #[error("unknown request")]
    UnknownRequest,
}

impl KvManager {
    /// Build a pool covering `capacity_tokens`, paged into `block_tokens`.
    pub fn new(capacity_tokens: usize, block_tokens: usize) -> Self {
        assert!(block_tokens > 0);
        let total_blocks = capacity_tokens / block_tokens;
        KvManager {
            block_tokens,
            total_blocks,
            free: (0..total_blocks as u32).rev().collect(),
            allocs: HashMap::new(),
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free.len()
    }

    /// Tokens that can still be admitted (conservative: whole free blocks).
    pub fn free_tokens(&self) -> usize {
        self.free.len() * self.block_tokens
    }

    pub fn capacity_tokens(&self) -> usize {
        self.total_blocks * self.block_tokens
    }

    /// Exact tokens currently stored for `id` (0 when absent).
    pub fn tokens_of(&self, id: RequestId) -> usize {
        self.allocs.get(&id).map(|a| a.tokens).unwrap_or(0)
    }

    pub fn holds(&self, id: RequestId) -> bool {
        self.allocs.contains_key(&id)
    }

    pub fn resident_requests(&self) -> impl Iterator<Item = RequestId> + '_ {
        self.allocs.keys().copied()
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Can `tokens` more tokens be admitted for a *new* request?
    pub fn can_fit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free.len()
    }

    /// Admit a request with an initial token count (post-prefill KV).
    pub fn admit(&mut self, id: RequestId, tokens: usize) -> Result<(), KvError> {
        debug_assert!(!self.allocs.contains_key(&id), "double admit {id}");
        let need = self.blocks_for(tokens.max(1));
        if need > self.free.len() {
            return Err(KvError::OutOfMemory);
        }
        let blocks = self.free.split_off(self.free.len() - need);
        self.allocs.insert(
            id,
            Alloc {
                blocks,
                tokens: tokens.max(1),
            },
        );
        Ok(())
    }

    /// Grow a resident request by `extra` tokens (decode step). On failure
    /// the request keeps its current allocation.
    pub fn grow(&mut self, id: RequestId, extra: usize) -> Result<(), KvError> {
        let alloc = self.allocs.get_mut(&id).ok_or(KvError::UnknownRequest)?;
        let new_tokens = alloc.tokens + extra;
        let need = new_tokens.div_ceil(self.block_tokens);
        let have = alloc.blocks.len();
        if need > have {
            let want = need - have;
            if want > self.free.len() {
                return Err(KvError::OutOfMemory);
            }
            let mut new_blocks = self.free.split_off(self.free.len() - want);
            alloc.blocks.append(&mut new_blocks);
        }
        alloc.tokens = new_tokens;
        Ok(())
    }

    /// Release a request's blocks (finish, eviction, or migration-out).
    pub fn release(&mut self, id: RequestId) -> Result<usize, KvError> {
        let alloc = self.allocs.remove(&id).ok_or(KvError::UnknownRequest)?;
        let tokens = alloc.tokens;
        self.free.extend(alloc.blocks);
        Ok(tokens)
    }

    /// Blocks needed to admit `tokens` (exposed for eviction planning).
    pub fn blocks_needed(&self, tokens: usize) -> usize {
        self.blocks_for(tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> KvManager {
        KvManager::new(1600, 16) // 100 blocks of 16 tokens
    }

    #[test]
    fn admit_grow_release_roundtrip() {
        let mut m = mgr();
        assert_eq!(m.total_blocks(), 100);
        m.admit(1, 100).unwrap(); // 7 blocks
        assert_eq!(m.used_blocks(), 7);
        assert_eq!(m.tokens_of(1), 100);
        m.grow(1, 12).unwrap(); // 112 tokens -> still 7 blocks
        assert_eq!(m.used_blocks(), 7);
        m.grow(1, 1).unwrap(); // 113 -> 8 blocks
        assert_eq!(m.used_blocks(), 8);
        assert_eq!(m.release(1).unwrap(), 113);
        assert_eq!(m.used_blocks(), 0);
        assert_eq!(m.free_blocks(), 100);
    }

    #[test]
    fn admission_control() {
        let mut m = mgr();
        assert!(m.can_fit(1600));
        assert!(!m.can_fit(1601));
        m.admit(1, 1590).unwrap(); // 100 blocks (1590/16 -> 100)
        assert_eq!(m.free_blocks(), 0);
        assert_eq!(m.admit(2, 1), Err(KvError::OutOfMemory));
        m.release(1).unwrap();
        m.admit(2, 1).unwrap();
        assert_eq!(m.used_blocks(), 1);
    }

    #[test]
    fn grow_failure_keeps_allocation() {
        let mut m = KvManager::new(64, 16); // 4 blocks
        m.admit(1, 48).unwrap(); // 3 blocks
        m.admit(2, 16).unwrap(); // 1 block -> pool full
        assert_eq!(m.grow(1, 32), Err(KvError::OutOfMemory));
        assert_eq!(m.tokens_of(1), 48); // unchanged
        m.release(2).unwrap();
        m.grow(1, 16).unwrap(); // now fits
        assert_eq!(m.tokens_of(1), 64);
    }

    #[test]
    fn unknown_request_errors() {
        let mut m = mgr();
        assert_eq!(m.grow(9, 1), Err(KvError::UnknownRequest));
        assert_eq!(m.release(9), Err(KvError::UnknownRequest));
        assert_eq!(m.tokens_of(9), 0);
        assert!(!m.holds(9));
    }

    #[test]
    fn zero_token_admit_rounds_up() {
        let mut m = mgr();
        m.admit(1, 0).unwrap();
        assert_eq!(m.tokens_of(1), 1);
        assert_eq!(m.used_blocks(), 1);
    }

    #[test]
    fn no_block_leaks_under_churn() {
        // Property: after any sequence of admit/grow/release, free + used
        // block counts always equal the pool size, and blocks are unique.
        let mut m = KvManager::new(3200, 16);
        let mut rng = crate::util::rng::Pcg::seeded(5);
        let mut live: Vec<RequestId> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..2000 {
            match rng.below(3) {
                0 => {
                    let toks = rng.below(200) + 1;
                    if m.admit(next_id, toks).is_ok() {
                        live.push(next_id);
                    }
                    next_id += 1;
                }
                1 if !live.is_empty() => {
                    let id = live[rng.below(live.len())];
                    let _ = m.grow(id, rng.below(40) + 1);
                }
                2 if !live.is_empty() => {
                    let idx = rng.below(live.len());
                    let id = live.swap_remove(idx);
                    m.release(id).unwrap();
                }
                _ => {}
            }
            assert_eq!(m.used_blocks() + m.free_blocks(), m.total_blocks());
        }
        for id in live {
            m.release(id).unwrap();
        }
        assert_eq!(m.free_blocks(), m.total_blocks());
        // Uniqueness: freeing everything restored exactly the pool.
        let mut all = m.free.clone();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), m.total_blocks());
    }
}
