//! Paged KV-cache manager (the PagedAttention-style memory substrate).
//!
//! Tracks block-granular allocations per request on one instance. The
//! simulator uses it for capacity accounting and eviction decisions; the
//! real engine uses it to bound admission on the tiny model. A free-list
//! allocator keeps alloc/free O(blocks) with zero steady-state heap churn
//! (hot-path requirement: every decode iteration may grow each request by
//! one token).
//!
//! Since the prefix-sharing cache (DESIGN.md §3.7) the allocator is
//! **refcounted**: a block may be referenced by several requests sharing a
//! prompt prefix, and/or *cache-marked* — retained after its owners left so
//! a later request with the same prefix skips the recompute. Cache-marked
//! blocks with no referents are **reclaimable capacity**: they sit on an
//! LRU list, count toward [`KvManager::free_tokens`], and are reclaimed on
//! demand when the free list runs dry (the reclaim log lets the owning
//! [`crate::prefix::PrefixIndex`] drop the matching chain entries).
//! Divergence inside a shared block is handled by copy-on-write: the
//! request gets a private block standing in for the copied content
//! ([`KvManager::admit_shared`]'s `partial` argument, and the grow-path
//! guard when a write frontier sits in a block with other referents).

use std::collections::{HashMap, VecDeque};

use crate::request::RequestId;

/// Block-granular paged allocator for one instance's KV memory.
#[derive(Debug)]
pub struct KvManager {
    /// Tokens per block (vLLM-style page size).
    block_tokens: usize,
    /// Total blocks in the pool.
    total_blocks: usize,
    /// Free block indices (LIFO for locality).
    free: Vec<u32>,
    /// Per-block count of requests referencing it.
    refcount: Vec<u32>,
    /// Per-block prefix-cache membership (set by the index layer).
    cached: Vec<bool>,
    /// LRU of reclaimable blocks (`cached && refcount == 0`), as
    /// `(block, stamp)` with lazy invalidation via `lru_stamp`.
    lru: VecDeque<(u32, u64)>,
    lru_stamp: Vec<u64>,
    next_stamp: u64,
    /// Count of reclaimable blocks (kept O(1); equals the live LRU set).
    reclaimable: usize,
    /// Cache blocks reclaimed by the allocator since the last
    /// [`KvManager::take_reclaimed`] — the index-sync log.
    reclaimed: Vec<u32>,
    /// Per-request allocation: block list + exact token count.
    allocs: HashMap<RequestId, Alloc>,
    /// Copy-on-write block copies performed (admission partial reuse +
    /// grow-path divergence).
    pub cow_copies: u64,
}

#[derive(Debug, Clone)]
struct Alloc {
    blocks: Vec<u32>,
    tokens: usize,
}

/// Why an allocation failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, thiserror::Error)]
pub enum KvError {
    #[error("out of KV blocks")]
    OutOfMemory,
    #[error("unknown request")]
    UnknownRequest,
}

impl KvManager {
    /// Build a pool covering `capacity_tokens`, paged into `block_tokens`.
    pub fn new(capacity_tokens: usize, block_tokens: usize) -> Self {
        assert!(block_tokens > 0);
        let total_blocks = capacity_tokens / block_tokens;
        KvManager {
            block_tokens,
            total_blocks,
            free: (0..total_blocks as u32).rev().collect(),
            refcount: vec![0; total_blocks],
            cached: vec![false; total_blocks],
            lru: VecDeque::new(),
            lru_stamp: vec![0; total_blocks],
            next_stamp: 0,
            reclaimable: 0,
            reclaimed: Vec::new(),
            allocs: HashMap::new(),
            cow_copies: 0,
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Strictly free blocks (not held by any request or the cache).
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Cache-marked blocks with no referents — capacity an admission can
    /// reclaim on demand.
    pub fn reclaimable_blocks(&self) -> usize {
        self.reclaimable
    }

    /// Blocks referenced by at least one live request.
    pub fn pinned_blocks(&self) -> usize {
        self.total_blocks - self.free.len() - self.reclaimable
    }

    /// Non-free blocks (pinned + reclaimable cache).
    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free.len()
    }

    /// Tokens that can still be admitted (conservative: whole blocks).
    /// Reclaimable cached blocks count — they are evicted on demand — so
    /// this stays *honest under sharing*: an admission of `free_tokens`
    /// tokens always succeeds (property-tested).
    pub fn free_tokens(&self) -> usize {
        (self.free.len() + self.reclaimable) * self.block_tokens
    }

    pub fn capacity_tokens(&self) -> usize {
        self.total_blocks * self.block_tokens
    }

    /// Exact tokens currently stored for `id` (0 when absent).
    pub fn tokens_of(&self, id: RequestId) -> usize {
        self.allocs.get(&id).map(|a| a.tokens).unwrap_or(0)
    }

    pub fn holds(&self, id: RequestId) -> bool {
        self.allocs.contains_key(&id)
    }

    pub fn resident_requests(&self) -> impl Iterator<Item = RequestId> + '_ {
        self.allocs.keys().copied()
    }

    /// The request's block list in token order (shared prefix first).
    pub fn blocks_of(&self, id: RequestId) -> Option<&[u32]> {
        self.allocs.get(&id).map(|a| a.blocks.as_slice())
    }

    /// Is `block` currently a prefix-cache entry?
    pub fn is_cached(&self, block: u32) -> bool {
        self.cached[block as usize]
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Can `tokens` more tokens be admitted for a *new* request?
    pub fn can_fit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free.len() + self.reclaimable
    }

    /// Can `tokens` be admitted when the first `shared_full.len()` blocks
    /// are cache references? Shared blocks that are currently reclaimable
    /// become pinned by the admission, so they cannot double as the private
    /// remainder — the math here matches [`KvManager::admit_shared`].
    pub fn can_admit_shared(&self, tokens: usize, shared_full: &[u32]) -> bool {
        let need = self
            .blocks_for(tokens.max(1))
            .saturating_sub(shared_full.len());
        let shared_unpinned = shared_full
            .iter()
            .filter(|&&b| self.refcount[b as usize] == 0)
            .count();
        need + shared_unpinned <= self.free.len() + self.reclaimable
    }

    /// Admit a request with an initial token count (post-prefill KV).
    pub fn admit(&mut self, id: RequestId, tokens: usize) -> Result<(), KvError> {
        self.admit_shared(id, tokens, &[], None)
    }

    /// Admit a request whose first `shared_full.len()` blocks reference
    /// cached prefix content (refcounted, zero recompute), optionally
    /// reusing one terminal partially-filled cached block by copy-on-write
    /// (`partial`: the source block — a private stand-in is allocated, the
    /// source stays cached untouched). The private remainder comes from the
    /// free list, reclaiming LRU cache blocks on demand.
    pub fn admit_shared(
        &mut self,
        id: RequestId,
        tokens: usize,
        shared_full: &[u32],
        partial: Option<(u32, usize)>,
    ) -> Result<(), KvError> {
        debug_assert!(!self.allocs.contains_key(&id), "double admit {id}");
        let tokens = tokens.max(1);
        debug_assert!(
            shared_full.len() * self.block_tokens < tokens,
            "shared prefix must leave room for a private tail"
        );
        if !self.can_admit_shared(tokens, shared_full) {
            return Err(KvError::OutOfMemory);
        }
        // Pin the shared blocks first so the reclamation the private tail
        // may trigger can never steal them.
        for &b in shared_full {
            let bi = b as usize;
            debug_assert!(
                self.cached[bi] || self.refcount[bi] > 0,
                "shared block {b} is neither cached nor referenced"
            );
            if self.refcount[bi] == 0 {
                // Leaves the reclaimable set; its LRU entry goes stale.
                self.reclaimable -= 1;
                self.lru_stamp[bi] = self.lru_stamp[bi].wrapping_add(1);
            }
            self.refcount[bi] += 1;
        }
        let need = self.blocks_for(tokens) - shared_full.len();
        let mut blocks: Vec<u32> = shared_full.to_vec();
        for _ in 0..need {
            let b = self.alloc_block().expect("capacity checked above");
            blocks.push(b);
        }
        if partial.is_some() {
            // The first private block stands in for the copied content.
            self.cow_copies += 1;
        }
        self.allocs.insert(id, Alloc { blocks, tokens });
        Ok(())
    }

    /// Pop a block for private use: free list first, then the LRU cache
    /// (appending to the reclaim log for index sync). Sets refcount to 1.
    fn alloc_block(&mut self) -> Option<u32> {
        let b = match self.free.pop() {
            Some(b) => b,
            None => {
                let b = self.pop_lru()?;
                self.cached[b as usize] = false;
                self.reclaimable -= 1;
                self.reclaimed.push(b);
                b
            }
        };
        debug_assert_eq!(self.refcount[b as usize], 0);
        debug_assert!(!self.cached[b as usize]);
        self.refcount[b as usize] = 1;
        Some(b)
    }

    /// Pop the least-recently-used valid reclaimable block.
    fn pop_lru(&mut self) -> Option<u32> {
        while let Some((b, stamp)) = self.lru.pop_front() {
            let bi = b as usize;
            if self.lru_stamp[bi] == stamp
                && self.cached[bi]
                && self.refcount[bi] == 0
            {
                return Some(b);
            }
        }
        None
    }

    /// Stamp `block` into the LRU as newly reclaimable.
    fn enter_lru(&mut self, block: u32) {
        self.next_stamp += 1;
        self.lru_stamp[block as usize] = self.next_stamp;
        self.lru.push_back((block, self.next_stamp));
        self.reclaimable += 1;
        self.maybe_compact_lru();
    }

    /// Move reclaimable `blocks` to most-recently-used (a cache hit's
    /// recency signal). Pinned or uncached blocks are left alone.
    pub fn touch_blocks(&mut self, blocks: &[u32]) {
        for &b in blocks {
            let bi = b as usize;
            if self.cached[bi] && self.refcount[bi] == 0 {
                self.next_stamp += 1;
                self.lru_stamp[bi] = self.next_stamp;
                self.lru.push_back((b, self.next_stamp));
            }
        }
        self.maybe_compact_lru();
    }

    /// Lazy invalidation leaves stale `(block, stamp)` entries behind; on
    /// a hit-heavy run with no memory pressure nothing would ever drain
    /// them, so bound the deque: once it exceeds twice the pool size, drop
    /// every entry whose stamp is no longer current (order-preserving, so
    /// recency is untouched).
    fn maybe_compact_lru(&mut self) {
        if self.lru.len() <= 2 * self.total_blocks.max(16) {
            return;
        }
        let stamps = &self.lru_stamp;
        let cached = &self.cached;
        let refcount = &self.refcount;
        self.lru.retain(|&(b, s)| {
            let bi = b as usize;
            stamps[bi] == s && cached[bi] && refcount[bi] == 0
        });
    }

    /// Register `block` as a prefix-cache entry (index insertion). A block
    /// with no referents becomes reclaimable immediately.
    pub fn mark_cached(&mut self, block: u32) {
        let bi = block as usize;
        if self.cached[bi] {
            return;
        }
        debug_assert!(
            self.refcount[bi] > 0,
            "cache mark of a free block {block}"
        );
        self.cached[bi] = true;
    }

    /// Drop `block`'s cache membership (index removal/replacement).
    /// Returns true when the block had no referents and went back to the
    /// free list.
    pub fn unmark_cached(&mut self, block: u32) -> bool {
        let bi = block as usize;
        if !self.cached[bi] {
            return false;
        }
        self.cached[bi] = false;
        if self.refcount[bi] == 0 {
            self.lru_stamp[bi] = self.lru_stamp[bi].wrapping_add(1);
            self.reclaimable -= 1;
            self.free.push(block);
            true
        } else {
            false
        }
    }

    /// Drain the log of cache blocks the allocator reclaimed, so the
    /// prefix index can forget the matching chain entries.
    pub fn take_reclaimed(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.reclaimed)
    }

    /// Drop one reference to `block`; a cached block with no referents left
    /// becomes reclaimable, an uncached one frees.
    fn release_ref(&mut self, block: u32) {
        let bi = block as usize;
        debug_assert!(self.refcount[bi] > 0, "double free of block {block}");
        self.refcount[bi] -= 1;
        if self.refcount[bi] == 0 {
            if self.cached[bi] {
                self.enter_lru(block);
            } else {
                self.free.push(block);
            }
        }
    }

    /// Grow a resident request by `extra` tokens (decode step). On failure
    /// the request keeps its current allocation. If the write frontier sits
    /// in a block shared with another request, the block is copied first
    /// (copy-on-write divergence).
    pub fn grow(&mut self, id: RequestId, extra: usize) -> Result<(), KvError> {
        let (have, old_tokens) = {
            let a = self.allocs.get(&id).ok_or(KvError::UnknownRequest)?;
            (a.blocks.len(), a.tokens)
        };
        let new_tokens = old_tokens + extra;
        let need = new_tokens.div_ceil(self.block_tokens);
        let tail = need.saturating_sub(have);
        // The next token lands inside the last block iff it is partial;
        // shared partial blocks must be copied before the write.
        let frontier = old_tokens % self.block_tokens != 0;
        let cow = frontier && {
            let fb = self.allocs[&id].blocks[old_tokens / self.block_tokens];
            self.refcount[fb as usize] > 1
        };
        if tail + usize::from(cow)
            > self.free.len() + self.reclaimable
        {
            return Err(KvError::OutOfMemory);
        }
        if cow {
            let fi = old_tokens / self.block_tokens;
            let old = self.allocs[&id].blocks[fi];
            let copy = self.alloc_block().expect("capacity checked");
            self.allocs.get_mut(&id).expect("resident").blocks[fi] = copy;
            self.release_ref(old);
            self.cow_copies += 1;
        }
        if tail > 0 {
            let mut newb = Vec::with_capacity(tail);
            for _ in 0..tail {
                newb.push(self.alloc_block().expect("capacity checked"));
            }
            self.allocs
                .get_mut(&id)
                .expect("resident")
                .blocks
                .extend(newb);
        }
        self.allocs.get_mut(&id).expect("resident").tokens = new_tokens;
        Ok(())
    }

    /// Release a request's blocks (finish, eviction, or migration-out).
    /// Cache-marked blocks are retained as reclaimable capacity; the rest
    /// free immediately.
    pub fn release(&mut self, id: RequestId) -> Result<usize, KvError> {
        let alloc = self.allocs.remove(&id).ok_or(KvError::UnknownRequest)?;
        let tokens = alloc.tokens;
        for b in alloc.blocks {
            self.release_ref(b);
        }
        Ok(tokens)
    }

    /// Blocks needed to admit `tokens` (exposed for eviction planning).
    pub fn blocks_needed(&self, tokens: usize) -> usize {
        self.blocks_for(tokens)
    }

    /// Internal-consistency audit for the property tests: every block is
    /// exactly one of free / pinned / reclaimable, refcounts equal the
    /// per-request membership counts, and the free list is duplicate-free.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen_free = vec![false; self.total_blocks];
        for &b in &self.free {
            let bi = b as usize;
            if bi >= self.total_blocks {
                return Err(format!("free block {b} out of range"));
            }
            if seen_free[bi] {
                return Err(format!("block {b} twice on the free list"));
            }
            seen_free[bi] = true;
            if self.refcount[bi] != 0 {
                return Err(format!("free block {b} has refcount"));
            }
            if self.cached[bi] {
                return Err(format!("free block {b} is cache-marked"));
            }
        }
        let mut expected_rc = vec![0u32; self.total_blocks];
        for (id, a) in &self.allocs {
            if a.blocks.len() != self.blocks_for(a.tokens.max(1)) {
                return Err(format!(
                    "request {id}: {} blocks for {} tokens",
                    a.blocks.len(),
                    a.tokens
                ));
            }
            for &b in &a.blocks {
                expected_rc[b as usize] += 1;
            }
        }
        let mut reclaimable = 0usize;
        for b in 0..self.total_blocks {
            if expected_rc[b] != self.refcount[b] {
                return Err(format!(
                    "block {b}: refcount {} but {} owners",
                    self.refcount[b], expected_rc[b]
                ));
            }
            if self.refcount[b] == 0 && !self.cached[b] && !seen_free[b] {
                return Err(format!("block {b} leaked (not free, not held)"));
            }
            if self.cached[b] && self.refcount[b] == 0 {
                reclaimable += 1;
            }
        }
        if reclaimable != self.reclaimable {
            return Err(format!(
                "reclaimable count {} but {} blocks qualify",
                self.reclaimable, reclaimable
            ));
        }
        if self.free.len() + self.reclaimable + self.pinned_blocks()
            != self.total_blocks
        {
            return Err("free + reclaimable + pinned != total".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> KvManager {
        KvManager::new(1600, 16) // 100 blocks of 16 tokens
    }

    #[test]
    fn admit_grow_release_roundtrip() {
        let mut m = mgr();
        assert_eq!(m.total_blocks(), 100);
        m.admit(1, 100).unwrap(); // 7 blocks
        assert_eq!(m.used_blocks(), 7);
        assert_eq!(m.tokens_of(1), 100);
        m.grow(1, 12).unwrap(); // 112 tokens -> still 7 blocks
        assert_eq!(m.used_blocks(), 7);
        m.grow(1, 1).unwrap(); // 113 -> 8 blocks
        assert_eq!(m.used_blocks(), 8);
        assert_eq!(m.release(1).unwrap(), 113);
        assert_eq!(m.used_blocks(), 0);
        assert_eq!(m.free_blocks(), 100);
        m.check_invariants().unwrap();
    }

    #[test]
    fn admission_control() {
        let mut m = mgr();
        assert!(m.can_fit(1600));
        assert!(!m.can_fit(1601));
        m.admit(1, 1590).unwrap(); // 100 blocks (1590/16 -> 100)
        assert_eq!(m.free_blocks(), 0);
        assert_eq!(m.admit(2, 1), Err(KvError::OutOfMemory));
        m.release(1).unwrap();
        m.admit(2, 1).unwrap();
        assert_eq!(m.used_blocks(), 1);
    }

    #[test]
    fn grow_failure_keeps_allocation() {
        let mut m = KvManager::new(64, 16); // 4 blocks
        m.admit(1, 48).unwrap(); // 3 blocks
        m.admit(2, 16).unwrap(); // 1 block -> pool full
        assert_eq!(m.grow(1, 32), Err(KvError::OutOfMemory));
        assert_eq!(m.tokens_of(1), 48); // unchanged
        m.release(2).unwrap();
        m.grow(1, 16).unwrap(); // now fits
        assert_eq!(m.tokens_of(1), 64);
    }

    #[test]
    fn unknown_request_errors() {
        let mut m = mgr();
        assert_eq!(m.grow(9, 1), Err(KvError::UnknownRequest));
        assert_eq!(m.release(9), Err(KvError::UnknownRequest));
        assert_eq!(m.tokens_of(9), 0);
        assert!(!m.holds(9));
    }

    #[test]
    fn zero_token_admit_rounds_up() {
        let mut m = mgr();
        m.admit(1, 0).unwrap();
        assert_eq!(m.tokens_of(1), 1);
        assert_eq!(m.used_blocks(), 1);
    }

    #[test]
    fn shared_admission_refcounts_and_retains() {
        let mut m = mgr();
        m.admit(1, 33).unwrap(); // 3 blocks
        let blocks = m.blocks_of(1).unwrap().to_vec();
        // Register the first two blocks as prefix-cache content.
        m.mark_cached(blocks[0]);
        m.mark_cached(blocks[1]);
        assert_eq!(m.reclaimable_blocks(), 0); // pinned while referenced

        // A second request shares the cached prefix.
        m.admit_shared(2, 40, &blocks[..2], None).unwrap();
        assert_eq!(m.tokens_of(2), 40);
        assert_eq!(m.blocks_of(2).unwrap()[..2], blocks[..2]);
        // 3 private + 2 shared + 1 private tail for request 2.
        assert_eq!(m.used_blocks(), 4);
        m.check_invariants().unwrap();

        // First owner leaves: shared blocks stay pinned by request 2.
        m.release(1).unwrap();
        assert_eq!(m.reclaimable_blocks(), 0);
        m.check_invariants().unwrap();

        // Second owner leaves: the cached prefix becomes reclaimable.
        m.release(2).unwrap();
        assert_eq!(m.reclaimable_blocks(), 2);
        assert_eq!(m.pinned_blocks(), 0);
        assert_eq!(m.free_tokens(), 100 * 16);
        m.check_invariants().unwrap();
    }

    #[test]
    fn partial_reuse_counts_cow() {
        let mut m = mgr();
        m.admit(1, 20).unwrap(); // 2 blocks, second partial
        let blocks = m.blocks_of(1).unwrap().to_vec();
        m.mark_cached(blocks[0]);
        m.mark_cached(blocks[1]);
        m.release(1).unwrap();
        assert_eq!(m.reclaimable_blocks(), 2);

        // Share the full block, copy-on-write the partial one.
        m.admit_shared(2, 25, &blocks[..1], Some((blocks[1], 4)))
            .unwrap();
        assert_eq!(m.cow_copies, 1);
        // The source partial block stays cached and reclaimable.
        assert!(m.is_cached(blocks[1]));
        assert_eq!(m.reclaimable_blocks(), 1);
        m.check_invariants().unwrap();
    }

    #[test]
    fn lru_reclaim_feeds_admissions_and_logs() {
        let mut m = KvManager::new(64, 16); // 4 blocks
        m.admit(1, 33).unwrap(); // 3 blocks
        let blocks = m.blocks_of(1).unwrap().to_vec();
        for &b in &blocks {
            m.mark_cached(b);
        }
        m.release(1).unwrap();
        assert_eq!(m.free_blocks(), 1);
        assert_eq!(m.reclaimable_blocks(), 3);
        assert!(m.can_fit(64));

        // free_tokens honesty: the full pool is admittable.
        m.admit(2, 64).unwrap();
        assert_eq!(m.free_blocks(), 0);
        assert_eq!(m.reclaimable_blocks(), 0);
        // The three cached blocks were reclaimed oldest-first and logged.
        let log = m.take_reclaimed();
        assert_eq!(log, blocks);
        assert!(m.take_reclaimed().is_empty());
        m.check_invariants().unwrap();
    }

    #[test]
    fn grow_cow_on_shared_frontier() {
        let mut m = mgr();
        m.admit(1, 20).unwrap();
        let blocks = m.blocks_of(1).unwrap().to_vec();
        // Force a *referenced* shared partial frontier (the scheduler only
        // produces this via admission CoW, but the allocator must guard).
        m.admit_shared(2, 33, &[], None).unwrap();
        let b2 = m.blocks_of(2).unwrap().to_vec();
        let _ = b2;
        // Manually alias: request 3 shares request 1's partial tail is not
        // constructible through the public API (partial reuse copies), so
        // exercise the guard through refcounts: share block 1 fully.
        m.mark_cached(blocks[0]);
        m.mark_cached(blocks[1]);
        m.release(1).unwrap();
        // Request 4 references both cached blocks; its frontier (token 32)
        // starts a fresh block, so growth never writes shared state.
        m.admit_shared(4, 33, &blocks[..2], None).unwrap();
        m.grow(4, 20).unwrap();
        assert_eq!(m.tokens_of(4), 53);
        m.check_invariants().unwrap();
    }

    #[test]
    fn unmark_cached_frees_unreferenced_blocks() {
        let mut m = mgr();
        m.admit(1, 32).unwrap();
        let blocks = m.blocks_of(1).unwrap().to_vec();
        m.mark_cached(blocks[0]);
        m.release(1).unwrap();
        assert_eq!(m.reclaimable_blocks(), 1);
        assert!(m.unmark_cached(blocks[0]));
        assert_eq!(m.reclaimable_blocks(), 0);
        assert_eq!(m.free_blocks(), 100);
        assert!(!m.unmark_cached(blocks[0])); // idempotent
        m.check_invariants().unwrap();
    }

    #[test]
    fn no_block_leaks_under_churn() {
        // Property: after any sequence of admit/grow/release, free + used
        // block counts always equal the pool size, and blocks are unique.
        let mut m = KvManager::new(3200, 16);
        let mut rng = crate::util::rng::Pcg::seeded(5);
        let mut live: Vec<RequestId> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..2000 {
            match rng.below(3) {
                0 => {
                    let toks = rng.below(200) + 1;
                    if m.admit(next_id, toks).is_ok() {
                        live.push(next_id);
                    }
                    next_id += 1;
                }
                1 if !live.is_empty() => {
                    let id = live[rng.below(live.len())];
                    let _ = m.grow(id, rng.below(40) + 1);
                }
                2 if !live.is_empty() => {
                    let idx = rng.below(live.len());
                    let id = live.swap_remove(idx);
                    m.release(id).unwrap();
                }
                _ => {}
            }
            assert_eq!(m.used_blocks() + m.free_blocks(), m.total_blocks());
        }
        m.check_invariants().unwrap();
        for id in live {
            m.release(id).unwrap();
        }
        assert_eq!(m.free_blocks(), m.total_blocks());
        // Uniqueness: freeing everything restored exactly the pool.
        let mut all = m.free.clone();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), m.total_blocks());
    }
}
