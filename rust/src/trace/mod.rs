//! Workload traces: dataset profiles, arrival-process generation, scaling.
//!
//! The paper's traces (company OOC production trace, Azure LLM Inference
//! Traces 2024) are not redistributable/downloadable here, so this module
//! synthesizes traces matching their *published statistics*: Table 5 length
//! means and Figure 1's temporal structure (hour/day tide + minute-scale
//! bursts). The paper's own trace-scaling procedure (§5.1.3) is implemented
//! verbatim in [`scaling`].

pub mod datasets;
pub mod generator;
pub mod io;
pub mod scaling;

pub use datasets::{DatasetProfile, LengthProfile};
pub use generator::{
    ArrivalPattern, PrefixProfile, PromptProfile, TraceGenerator, TraceSpec,
};
pub use scaling::scale_trace;

use crate::request::{Class, Request};

/// A generated or loaded workload trace: requests sorted by arrival time.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub requests: Vec<Request>,
}

impl Trace {
    pub fn new(mut requests: Vec<Request>) -> Self {
        requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        Trace { requests }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    pub fn duration(&self) -> f64 {
        self.requests.last().map(|r| r.arrival).unwrap_or(0.0)
    }

    pub fn count_class(&self, class: Class) -> usize {
        self.requests.iter().filter(|r| r.class == class).count()
    }

    /// Merge two traces (e.g. online + offline), re-sorting by arrival and
    /// re-assigning ids to stay unique.
    pub fn merge(self, other: Trace) -> Trace {
        let mut all = self.requests;
        all.extend(other.requests);
        all.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        for (i, r) in all.iter_mut().enumerate() {
            r.id = i as u64;
        }
        Trace { requests: all }
    }

    /// Per-bucket request counts — the Fig. 1 rate series.
    pub fn rate_series(&self, bucket_s: f64) -> Vec<usize> {
        if self.requests.is_empty() {
            return vec![];
        }
        let buckets = (self.duration() / bucket_s).floor() as usize + 1;
        let mut counts = vec![0usize; buckets];
        for r in &self.requests {
            let b = (r.arrival / bucket_s) as usize;
            counts[b.min(buckets - 1)] += 1;
        }
        counts
    }

    /// Mean prompt/output lengths (Table 5 reproduction).
    pub fn mean_lengths(&self, class: Option<Class>) -> (f64, f64) {
        let sel: Vec<&Request> = self
            .requests
            .iter()
            .filter(|r| class.map(|c| r.class == c).unwrap_or(true))
            .collect();
        if sel.is_empty() {
            return (0.0, 0.0);
        }
        let n = sel.len() as f64;
        let p = sel.iter().map(|r| r.prompt_len as f64).sum::<f64>() / n;
        let o = sel.iter().map(|r| r.output_len as f64).sum::<f64>() / n;
        (p, o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, t: f64) -> Request {
        Request::new(id, Class::Online, t, 10, 10)
    }

    #[test]
    fn new_sorts_by_arrival() {
        let t = Trace::new(vec![req(0, 5.0), req(1, 1.0), req(2, 3.0)]);
        let times: Vec<f64> = t.requests.iter().map(|r| r.arrival).collect();
        assert_eq!(times, vec![1.0, 3.0, 5.0]);
        assert_eq!(t.duration(), 5.0);
    }

    #[test]
    fn merge_reassigns_ids() {
        let a = Trace::new(vec![req(0, 1.0), req(1, 4.0)]);
        let b = Trace::new(vec![req(0, 2.0)]);
        let m = a.merge(b);
        assert_eq!(m.len(), 3);
        let ids: Vec<u64> = m.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        assert!(m.requests.windows(2).all(|w| w[0].arrival <= w[1].arrival));
    }

    #[test]
    fn rate_series_buckets() {
        let t = Trace::new(vec![req(0, 0.1), req(1, 0.2), req(2, 1.5), req(3, 2.9)]);
        assert_eq!(t.rate_series(1.0), vec![2, 1, 1]);
        assert!(Trace::default().rate_series(60.0).is_empty());
    }

    #[test]
    fn mean_lengths_by_class() {
        let mut reqs = vec![
            Request::new(0, Class::Online, 0.0, 100, 10),
            Request::new(1, Class::Offline, 0.0, 300, 30),
        ];
        reqs.push(Request::new(2, Class::Online, 0.0, 200, 20));
        let t = Trace::new(reqs);
        let (p, o) = t.mean_lengths(Some(Class::Online));
        assert_eq!((p, o), (150.0, 15.0));
        let (p, _) = t.mean_lengths(None);
        assert_eq!(p, 200.0);
        assert_eq!(t.count_class(Class::Offline), 1);
    }
}
