//! Dataset profiles matching the paper's Table 5 statistics.
//!
//! | Dataset       | Avg prompt | Avg output |
//! |---------------|-----------:|-----------:|
//! | OOC (Online)  |    1892.47 |    1062.62 |
//! | OOC (Offline) |    1200.52 |     671.51 |
//! | Azure Conv    |    1512.30 |      98.75 |
//! | Azure Code    |    2317.18 |      22.74 |
//!
//! Lengths are sampled lognormally with these arithmetic means; the sigma
//! values are chosen to produce realistic heavy tails (Azure Code's short
//! outputs are much tighter than OOC's long free-form generations).

use crate::util::rng::Pcg;

/// Lognormal length distribution hitting a target arithmetic mean.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LengthProfile {
    pub mean: f64,
    pub sigma: f64,
    /// Hard clamp bounds (tokens).
    pub min: usize,
    pub max: usize,
}

impl LengthProfile {
    pub fn new(mean: f64, sigma: f64, min: usize, max: usize) -> Self {
        LengthProfile {
            mean,
            sigma,
            min,
            max,
        }
    }

    pub fn sample(&self, rng: &mut Pcg) -> usize {
        let v = rng.lognormal_mean(self.mean, self.sigma).round() as usize;
        v.clamp(self.min, self.max)
    }
}

/// Arrival-fluctuation shape knobs (Figure 1's visual structure).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FluctuationProfile {
    /// Relative amplitude of the daily tide (0 = flat, 1 = full swing).
    pub tide_amplitude: f64,
    /// Expected bursts per hour.
    pub bursts_per_hour: f64,
    /// Mean burst duration (s).
    pub burst_duration_s: f64,
    /// Multiplier applied to the base rate during a burst.
    pub burst_multiplier: f64,
}

/// A named dataset: request-length profiles + arrival fluctuation shape.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetProfile {
    pub name: &'static str,
    pub prompt: LengthProfile,
    pub output: LengthProfile,
    pub fluctuation: FluctuationProfile,
}

impl DatasetProfile {
    /// OOC online portion: long prompts AND long streamed outputs; strong
    /// bursts (production chat traffic).
    pub fn ooc_online() -> Self {
        DatasetProfile {
            name: "ooc-online",
            prompt: LengthProfile::new(1892.47, 0.9, 16, 16384),
            output: LengthProfile::new(1062.62, 0.8, 1, 8192),
            fluctuation: FluctuationProfile {
                tide_amplitude: 0.6,
                bursts_per_hour: 6.0,
                burst_duration_s: 120.0,
                burst_multiplier: 2.5,
            },
        }
    }

    /// OOC offline portion: batch analytics/annotation jobs.
    pub fn ooc_offline() -> Self {
        DatasetProfile {
            name: "ooc-offline",
            prompt: LengthProfile::new(1200.52, 0.8, 16, 16384),
            output: LengthProfile::new(671.51, 0.8, 1, 8192),
            // Offline arrivals are rate-controlled by the experiment, not
            // bursty; fluctuation is unused but kept flat for completeness.
            fluctuation: FluctuationProfile {
                tide_amplitude: 0.0,
                bursts_per_hour: 0.0,
                burst_duration_s: 0.0,
                burst_multiplier: 1.0,
            },
        }
    }

    /// Azure 2024 conversation trace: chat-length prompts, short answers.
    pub fn azure_conv() -> Self {
        DatasetProfile {
            name: "azure-conv",
            prompt: LengthProfile::new(1512.30, 1.0, 8, 16384),
            output: LengthProfile::new(98.75, 0.9, 1, 2048),
            fluctuation: FluctuationProfile {
                tide_amplitude: 0.5,
                bursts_per_hour: 4.0,
                burst_duration_s: 180.0,
                burst_multiplier: 2.0,
            },
        }
    }

    /// Azure 2024 code trace: long contexts, tiny completions, spiky.
    pub fn azure_code() -> Self {
        DatasetProfile {
            name: "azure-code",
            prompt: LengthProfile::new(2317.18, 1.1, 8, 16384),
            output: LengthProfile::new(22.74, 0.7, 1, 512),
            fluctuation: FluctuationProfile {
                tide_amplitude: 0.7,
                bursts_per_hour: 10.0,
                burst_duration_s: 60.0,
                burst_multiplier: 3.0,
            },
        }
    }

    pub fn by_name(name: &str) -> anyhow::Result<Self> {
        match name {
            "ooc-online" | "ooc" => Ok(Self::ooc_online()),
            "ooc-offline" => Ok(Self::ooc_offline()),
            "azure-conv" => Ok(Self::azure_conv()),
            "azure-code" => Ok(Self::azure_code()),
            other => anyhow::bail!("unknown dataset `{other}`"),
        }
    }

    /// The three online/offline experiment configurations of §5.1.2: each
    /// pairs an online trace with the OOC offline request pool.
    pub fn experiment_pairs() -> Vec<(&'static str, DatasetProfile, DatasetProfile)> {
        vec![
            ("OOC", Self::ooc_online(), Self::ooc_offline()),
            ("Azure Conv", Self::azure_conv(), Self::ooc_offline()),
            ("Azure Code", Self::azure_code(), Self::ooc_offline()),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn length_profile_hits_mean() {
        let mut rng = Pcg::seeded(0);
        let p = LengthProfile::new(1892.47, 0.9, 16, 16384);
        let n = 60_000;
        let mean: f64 =
            (0..n).map(|_| p.sample(&mut rng) as f64).sum::<f64>() / n as f64;
        // Clamping trims the extreme tail slightly; allow 6%.
        assert!((mean / 1892.47 - 1.0).abs() < 0.06, "mean {mean}");
    }

    #[test]
    fn length_profile_clamps() {
        let mut rng = Pcg::seeded(1);
        let p = LengthProfile::new(100.0, 2.0, 50, 150);
        for _ in 0..1000 {
            let v = p.sample(&mut rng);
            assert!((50..=150).contains(&v));
        }
    }

    #[test]
    fn table5_means_encoded() {
        assert_eq!(DatasetProfile::ooc_online().prompt.mean, 1892.47);
        assert_eq!(DatasetProfile::ooc_online().output.mean, 1062.62);
        assert_eq!(DatasetProfile::ooc_offline().prompt.mean, 1200.52);
        assert_eq!(DatasetProfile::ooc_offline().output.mean, 671.51);
        assert_eq!(DatasetProfile::azure_conv().prompt.mean, 1512.30);
        assert_eq!(DatasetProfile::azure_conv().output.mean, 98.75);
        assert_eq!(DatasetProfile::azure_code().prompt.mean, 2317.18);
        assert_eq!(DatasetProfile::azure_code().output.mean, 22.74);
    }

    #[test]
    fn by_name_and_pairs() {
        assert!(DatasetProfile::by_name("azure-conv").is_ok());
        assert!(DatasetProfile::by_name("mmlu").is_err());
        let pairs = DatasetProfile::experiment_pairs();
        assert_eq!(pairs.len(), 3);
        for (_, _online, offline) in &pairs {
            assert_eq!(offline.name, "ooc-offline");
        }
    }
}
