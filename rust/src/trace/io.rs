//! Trace file I/O (JSON) — export generated traces, import external ones.
//!
//! Format: `{"requests": [{"class": "online", "arrival": 1.5,
//! "prompt_len": 100, "output_len": 50}, ...]}` — the same fields a
//! de-identified production trace (like the paper's OOC dataset) would
//! carry.

use std::path::Path;

use crate::request::{Class, Request};
use crate::util::json::Json;

use super::Trace;

pub fn trace_to_json(trace: &Trace) -> Json {
    let requests: Vec<Json> = trace
        .requests
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("class", Json::Str(r.class.name().to_string())),
                ("arrival", Json::Num(r.arrival)),
                ("prompt_len", Json::Num(r.prompt_len as f64)),
                ("output_len", Json::Num(r.output_len as f64)),
            ])
        })
        .collect();
    Json::obj(vec![("requests", Json::Arr(requests))])
}

pub fn trace_from_json(v: &Json) -> anyhow::Result<Trace> {
    let arr = v
        .get("requests")
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("trace file missing `requests` array"))?;
    let mut requests = Vec::with_capacity(arr.len());
    for (i, item) in arr.iter().enumerate() {
        let class = match item.req_str("class")? {
            "online" => Class::Online,
            "offline" => Class::Offline,
            other => anyhow::bail!("request {i}: unknown class `{other}`"),
        };
        requests.push(Request::new(
            i as u64,
            class,
            item.req_f64("arrival")?,
            item.req_usize("prompt_len")?,
            item.req_usize("output_len")?,
        ));
    }
    Ok(Trace::new(requests))
}

pub fn save_trace(trace: &Trace, path: &Path) -> anyhow::Result<()> {
    std::fs::write(path, trace_to_json(trace).to_string())
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
}

pub fn load_trace(path: &Path) -> anyhow::Result<Trace> {
    trace_from_json(&Json::parse_file(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::datasets::DatasetProfile;
    use crate::trace::generator::{offline_trace, online_trace};

    #[test]
    fn roundtrip_through_file() {
        let t = online_trace(DatasetProfile::azure_conv(), 1.0, 300.0, 5)
            .merge(offline_trace(DatasetProfile::ooc_offline(), 0.5, 300.0, 6));
        let dir = std::env::temp_dir().join("ooco_trace_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.json");
        save_trace(&t, &path).unwrap();
        let t2 = load_trace(&path).unwrap();
        assert_eq!(t.len(), t2.len());
        for (a, b) in t.requests.iter().zip(&t2.requests) {
            assert_eq!(a.class, b.class);
            assert!((a.arrival - b.arrival).abs() < 1e-9);
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.output_len, b.output_len);
        }
    }

    #[test]
    fn rejects_bad_class() {
        let v = Json::parse(
            r#"{"requests": [{"class": "turbo", "arrival": 0, "prompt_len": 1, "output_len": 1}]}"#,
        )
        .unwrap();
        assert!(trace_from_json(&v).is_err());
    }

    #[test]
    fn rejects_missing_requests() {
        assert!(trace_from_json(&Json::parse("{}").unwrap()).is_err());
    }
}
