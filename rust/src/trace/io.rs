//! Trace file I/O (JSON) — export generated traces, import external ones.
//!
//! Format: `{"requests": [{"class": "online", "arrival": 1.5,
//! "prompt_len": 100, "output_len": 50}, ...]}` — the same fields a
//! de-identified production trace (like the paper's OOC dataset) would
//! carry. Shared-prefix declarations (DESIGN.md §3.7) ride as an optional
//! pair per request: `"prefix_id"` (the family, serialized as a string —
//! u64 families do not fit a JSON double) and `"prefix_len"` (the
//! shareable span, `1..=prompt_len`). Either both are present or neither.

use std::path::Path;

use crate::request::{Class, Request};
use crate::util::json::Json;

use super::Trace;

pub fn trace_to_json(trace: &Trace) -> Json {
    let requests: Vec<Json> = trace
        .requests
        .iter()
        .map(|r| {
            let mut fields = vec![
                ("class", Json::Str(r.class.name().to_string())),
                ("arrival", Json::Num(r.arrival)),
                ("prompt_len", Json::Num(r.prompt_len as f64)),
                ("output_len", Json::Num(r.output_len as f64)),
            ];
            if let Some(p) = r.prefix {
                fields.push(("prefix_id", Json::Str(p.family.to_string())));
                fields.push(("prefix_len", Json::Num(p.len as f64)));
            }
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![("requests", Json::Arr(requests))])
}

pub fn trace_from_json(v: &Json) -> anyhow::Result<Trace> {
    let arr = v
        .get("requests")
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("trace file missing `requests` array"))?;
    let mut requests = Vec::with_capacity(arr.len());
    for (i, item) in arr.iter().enumerate() {
        let class = match item.req_str("class")? {
            "online" => Class::Online,
            "offline" => Class::Offline,
            other => anyhow::bail!("request {i}: unknown class `{other}`"),
        };
        let prompt_len = item.req_usize("prompt_len")?;
        let mut req = Request::new(
            i as u64,
            class,
            item.req_f64("arrival")?,
            prompt_len,
            item.req_usize("output_len")?,
        );
        match (item.get("prefix_id"), item.get("prefix_len")) {
            (Json::Null, Json::Null) => {}
            (Json::Null, _) => {
                anyhow::bail!("request {i}: prefix_len without prefix_id")
            }
            (_, Json::Null) => {
                anyhow::bail!("request {i}: prefix_id without prefix_len")
            }
            (id, len) => {
                let family = id
                    .as_str()
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "request {i}: prefix_id must be a string"
                        )
                    })?
                    .parse::<u64>()
                    .map_err(|e| {
                        anyhow::anyhow!("request {i}: bad prefix_id: {e}")
                    })?;
                let len = len.as_usize().ok_or_else(|| {
                    anyhow::anyhow!(
                        "request {i}: prefix_len must be a non-negative \
                         integer"
                    )
                })?;
                anyhow::ensure!(
                    len >= 1 && len <= prompt_len,
                    "request {i}: prefix_len {len} outside 1..=prompt_len \
                     ({prompt_len})"
                );
                req = req.with_prefix(family, len);
            }
        }
        requests.push(req);
    }
    Ok(Trace::new(requests))
}

pub fn save_trace(trace: &Trace, path: &Path) -> anyhow::Result<()> {
    std::fs::write(path, trace_to_json(trace).to_string())
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
}

pub fn load_trace(path: &Path) -> anyhow::Result<Trace> {
    trace_from_json(&Json::parse_file(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::datasets::DatasetProfile;
    use crate::trace::generator::{
        offline_trace, offline_trace_with_prefix, online_trace, PrefixProfile,
    };

    #[test]
    fn roundtrip_through_file() {
        let t = online_trace(DatasetProfile::azure_conv(), 1.0, 300.0, 5)
            .merge(offline_trace(DatasetProfile::ooc_offline(), 0.5, 300.0, 6));
        let dir = std::env::temp_dir().join("ooco_trace_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.json");
        save_trace(&t, &path).unwrap();
        let t2 = load_trace(&path).unwrap();
        assert_eq!(t.len(), t2.len());
        for (a, b) in t.requests.iter().zip(&t2.requests) {
            assert_eq!(a.class, b.class);
            assert!((a.arrival - b.arrival).abs() < 1e-9);
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.output_len, b.output_len);
            assert_eq!(a.prefix, b.prefix);
        }
    }

    #[test]
    fn prefix_fields_roundtrip_exactly() {
        // Families exceed 2^53, so string serialization is load-bearing:
        // a Num would silently round.
        let t = online_trace(DatasetProfile::azure_conv(), 0.5, 200.0, 5)
            .merge(offline_trace_with_prefix(
                DatasetProfile::ooc_offline(),
                1.0,
                200.0,
                PrefixProfile::FewShot { groups: 3, prefix_len: 640 },
                6,
            ));
        assert!(t.requests.iter().any(|r| r.prefix.is_some()));
        assert!(t.requests.iter().any(|r| r.prefix.is_none()));
        let t2 = trace_from_json(&trace_to_json(&t)).unwrap();
        for (a, b) in t.requests.iter().zip(&t2.requests) {
            assert_eq!(a.prefix, b.prefix, "request {}", a.id);
        }
    }

    #[test]
    fn rejects_malformed_prefix_declarations() {
        let base = r#"{"class": "offline", "arrival": 0, "prompt_len": 100, "output_len": 10"#;
        for (frag, why) in [
            (r#", "prefix_len": 50}"#, "prefix_len without prefix_id"),
            (r#", "prefix_id": "7"}"#, "prefix_id without prefix_len"),
            (r#", "prefix_id": "x9", "prefix_len": 50}"#, "non-numeric id"),
            (r#", "prefix_id": 7, "prefix_len": 50}"#, "id must be string"),
            (r#", "prefix_id": "7", "prefix_len": 0}"#, "zero span"),
            (r#", "prefix_id": "7", "prefix_len": 101}"#, "span > prompt"),
        ] {
            let v = Json::parse(&format!(
                r#"{{"requests": [{base}{frag}]}}"#
            ))
            .unwrap();
            assert!(trace_from_json(&v).is_err(), "accepted: {why}");
        }
        // And the well-formed declaration parses.
        let v = Json::parse(&format!(
            r#"{{"requests": [{base}, "prefix_id": "18446744073709551615", "prefix_len": 100}}]}}"#
        ))
        .unwrap();
        let t = trace_from_json(&v).unwrap();
        let p = t.requests[0].prefix.unwrap();
        assert_eq!(p.family, u64::MAX);
        assert_eq!(p.len, 100);
    }

    #[test]
    fn rejects_bad_class() {
        let v = Json::parse(
            r#"{"requests": [{"class": "turbo", "arrival": 0, "prompt_len": 1, "output_len": 1}]}"#,
        )
        .unwrap();
        assert!(trace_from_json(&v).is_err());
    }

    #[test]
    fn rejects_missing_requests() {
        assert!(trace_from_json(&Json::parse("{}").unwrap()).is_err());
    }
}
