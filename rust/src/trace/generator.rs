//! Arrival-process generation: Figure 1's tide + burst structure.
//!
//! Online traffic is a non-homogeneous Poisson process whose rate function
//! combines a sinusoidal daily tide with minute-scale multiplicative bursts;
//! sampling uses Lewis–Shedler thinning so the generated trace is an exact
//! draw from the rate function. Offline traffic is uniform-QPS (the paper
//! regulates offline load that way in §5.2).
//!
//! Shared-prefix workload families (DESIGN.md §3.7) ride on the same
//! machinery: a [`PrefixProfile`] declares how requests share prompt
//! prefixes — one system prompt, few-shot template groups, or multi-turn
//! agentic conversations ([`agentic_trace`]) whose context grows turn over
//! turn.

use crate::request::{Class, Request};
use crate::util::rng::Pcg;

use super::datasets::DatasetProfile;
use super::Trace;

/// Arrival pattern selector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalPattern {
    /// Tide + bursts non-homogeneous Poisson (online services).
    Fluctuating,
    /// Constant-rate Poisson (offline QPS control uses uniform spacing;
    /// Poisson here models the submission jitter of batch producers).
    UniformQps,
}

/// Shared-prefix structure of a synthesized workload (DESIGN.md §3.7).
/// The declared prefix is *prepended* to the dataset-sampled prompt, so
/// family members really do share their first `prefix_len` tokens.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PrefixProfile {
    /// Independent prompts (the pre-§3.7 behaviour).
    None,
    /// Every request shares one system prompt of `prefix_len` tokens.
    SharedSystem { prefix_len: usize },
    /// Requests draw one of `groups` few-shot templates of `prefix_len`
    /// tokens each.
    FewShot { groups: usize, prefix_len: usize },
    /// Multi-turn agentic conversations. Not expressible as a per-arrival
    /// decoration — use [`agentic_trace`]; [`TraceGenerator`] treats this
    /// variant like [`PrefixProfile::None`].
    Agentic { conversations: usize, turns: usize },
}

impl PrefixProfile {
    pub const DEFAULT_SHARED: PrefixProfile =
        PrefixProfile::SharedSystem { prefix_len: 1024 };
    pub const DEFAULT_FEW_SHOT: PrefixProfile =
        PrefixProfile::FewShot { groups: 8, prefix_len: 1024 };
    pub const DEFAULT_AGENTIC: PrefixProfile =
        PrefixProfile::Agentic { conversations: 16, turns: 6 };
}

impl std::str::FromStr for PrefixProfile {
    type Err = anyhow::Error;

    /// Parse `none`, `shared-system`, `few-shot`, `agentic`, or the
    /// parameterized forms `Display` emits — e.g.
    /// `shared-system(len=2048)`, `few-shot(groups=4,len=512)`,
    /// `agentic(convs=32,turns=8)` (keys optional, any order).
    fn from_str(name: &str) -> anyhow::Result<PrefixProfile> {
        fn params<'a>(
            body: &'a str,
            kind: &str,
        ) -> anyhow::Result<Vec<(&'a str, usize)>> {
            let mut out = Vec::new();
            for tok in body.split(',').filter(|t| !t.trim().is_empty()) {
                let (k, v) = tok.trim().split_once('=').ok_or_else(|| {
                    anyhow::anyhow!("bad {kind} parameter `{tok}`")
                })?;
                out.push((k.trim(), v.trim().parse::<usize>()?));
            }
            Ok(out)
        }
        match name {
            "none" => return Ok(PrefixProfile::None),
            "shared-system" => return Ok(Self::DEFAULT_SHARED),
            "few-shot" => return Ok(Self::DEFAULT_FEW_SHOT),
            "agentic" => return Ok(Self::DEFAULT_AGENTIC),
            _ => {}
        }
        if let Some(body) = name
            .strip_prefix("shared-system(")
            .and_then(|s| s.strip_suffix(')'))
        {
            let mut prefix_len = match Self::DEFAULT_SHARED {
                PrefixProfile::SharedSystem { prefix_len } => prefix_len,
                _ => unreachable!(),
            };
            for (k, v) in params(body, "shared-system")? {
                match k {
                    "len" | "prefix_len" => prefix_len = v,
                    _ => anyhow::bail!("unknown shared-system parameter `{k}`"),
                }
            }
            anyhow::ensure!(prefix_len > 0, "prefix_len must be positive");
            return Ok(PrefixProfile::SharedSystem { prefix_len });
        }
        if let Some(body) = name
            .strip_prefix("few-shot(")
            .and_then(|s| s.strip_suffix(')'))
        {
            let (mut groups, mut prefix_len) = match Self::DEFAULT_FEW_SHOT {
                PrefixProfile::FewShot { groups, prefix_len } => {
                    (groups, prefix_len)
                }
                _ => unreachable!(),
            };
            for (k, v) in params(body, "few-shot")? {
                match k {
                    "groups" => groups = v,
                    "len" | "prefix_len" => prefix_len = v,
                    _ => anyhow::bail!("unknown few-shot parameter `{k}`"),
                }
            }
            anyhow::ensure!(
                groups > 0 && prefix_len > 0,
                "few-shot needs positive groups and prefix_len"
            );
            return Ok(PrefixProfile::FewShot { groups, prefix_len });
        }
        if let Some(body) = name
            .strip_prefix("agentic(")
            .and_then(|s| s.strip_suffix(')'))
        {
            let (mut conversations, mut turns) = match Self::DEFAULT_AGENTIC {
                PrefixProfile::Agentic { conversations, turns } => {
                    (conversations, turns)
                }
                _ => unreachable!(),
            };
            for (k, v) in params(body, "agentic")? {
                match k {
                    "convs" | "conversations" => conversations = v,
                    "turns" => turns = v,
                    _ => anyhow::bail!("unknown agentic parameter `{k}`"),
                }
            }
            anyhow::ensure!(
                conversations > 0 && turns > 0,
                "agentic needs positive conversations and turns"
            );
            return Ok(PrefixProfile::Agentic { conversations, turns });
        }
        anyhow::bail!("unknown prefix profile `{name}`")
    }
}

impl std::fmt::Display for PrefixProfile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PrefixProfile::None => f.write_str("none"),
            PrefixProfile::SharedSystem { prefix_len } => {
                write!(f, "shared-system(len={prefix_len})")
            }
            PrefixProfile::FewShot { groups, prefix_len } => {
                write!(f, "few-shot(groups={groups},len={prefix_len})")
            }
            PrefixProfile::Agentic { conversations, turns } => {
                write!(f, "agentic(convs={conversations},turns={turns})")
            }
        }
    }
}

/// Prompt-length override profile (DESIGN.md §3.8): replaces a dataset's
/// prompt distribution with a long-prompt / heavy-tail one, the workload
/// family the chunked-prefill iteration model exists for (agentic
/// contexts, retrieval-stuffed prompts). Selected `--prefix-profile`-style
/// on the CLI (`--prompt-profile`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PromptProfile {
    /// Keep the dataset's own prompt distribution.
    Dataset,
    /// Heavy-tailed lognormal with arithmetic mean `mean`: large `sigma`
    /// puts substantial mass near `max`, so single prompts genuinely
    /// dominate exclusive-step iterations.
    LongPrompt { mean: usize, sigma: f64, max: usize },
}

impl PromptProfile {
    pub const DEFAULT_LONG: PromptProfile = PromptProfile::LongPrompt {
        mean: 6000,
        sigma: 1.2,
        max: 16384,
    };

    /// Apply the override to a dataset (no-op for [`PromptProfile::Dataset`]).
    pub fn apply(&self, ds: &super::datasets::DatasetProfile) -> super::datasets::DatasetProfile {
        match *self {
            PromptProfile::Dataset => ds.clone(),
            PromptProfile::LongPrompt { mean, sigma, max } => {
                let mut out = ds.clone();
                out.prompt = super::datasets::LengthProfile::new(
                    mean as f64,
                    sigma,
                    64.min(max),
                    max,
                );
                out
            }
        }
    }

    /// JSON form (the `Display` string), round-trippable via
    /// [`PromptProfile::from_json`].
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::Str(self.to_string())
    }

    pub fn from_json(
        v: &crate::util::json::Json,
    ) -> anyhow::Result<PromptProfile> {
        match v {
            crate::util::json::Json::Str(s) => s.parse(),
            other => {
                anyhow::bail!("prompt profile must be a string, got {other:?}")
            }
        }
    }
}

impl std::str::FromStr for PromptProfile {
    type Err = anyhow::Error;

    /// Parse `dataset`, `long-prompt`, or the parameterized form `Display`
    /// emits — `long-prompt(mean=6000,sigma=1.2,max=16384)` (keys
    /// optional, any order).
    fn from_str(name: &str) -> anyhow::Result<PromptProfile> {
        match name {
            "dataset" | "default" | "none" => {
                return Ok(PromptProfile::Dataset)
            }
            "long-prompt" | "heavy-tail" => {
                return Ok(Self::DEFAULT_LONG)
            }
            _ => {}
        }
        if let Some(body) = name
            .strip_prefix("long-prompt(")
            .and_then(|s| s.strip_suffix(')'))
        {
            let (mut mean, mut sigma, mut max) = match Self::DEFAULT_LONG {
                PromptProfile::LongPrompt { mean, sigma, max } => {
                    (mean, sigma, max)
                }
                _ => unreachable!(),
            };
            for tok in body.split(',').filter(|t| !t.trim().is_empty()) {
                let (k, v) = tok.trim().split_once('=').ok_or_else(|| {
                    anyhow::anyhow!("bad long-prompt parameter `{tok}`")
                })?;
                match k.trim() {
                    "mean" => mean = v.trim().parse::<usize>()?,
                    "sigma" => sigma = v.trim().parse::<f64>()?,
                    "max" => max = v.trim().parse::<usize>()?,
                    other => anyhow::bail!(
                        "unknown long-prompt parameter `{other}`"
                    ),
                }
            }
            anyhow::ensure!(
                mean > 0 && max >= mean && sigma > 0.0,
                "long-prompt needs mean > 0, max >= mean, sigma > 0"
            );
            return Ok(PromptProfile::LongPrompt { mean, sigma, max });
        }
        anyhow::bail!("unknown prompt profile `{name}`")
    }
}

impl std::fmt::Display for PromptProfile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PromptProfile::Dataset => f.write_str("dataset"),
            PromptProfile::LongPrompt { mean, sigma, max } => {
                write!(f, "long-prompt(mean={mean},sigma={sigma},max={max})")
            }
        }
    }
}

/// Everything needed to synthesize one class's trace.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    pub dataset: DatasetProfile,
    pub class: Class,
    pub pattern: ArrivalPattern,
    /// Mean arrival rate (requests/s) before fluctuation.
    pub base_rate: f64,
    /// Trace duration (s).
    pub duration_s: f64,
    /// Phase offset into the day (s) — where on the tide the trace starts.
    pub day_phase_s: f64,
    /// Shared-prefix structure ([`PrefixProfile::None`] = independent
    /// prompts; `Agentic` is ignored here — use [`agentic_trace`]).
    pub prefix: PrefixProfile,
    pub seed: u64,
}

/// Generator holding the burst schedule derived from the spec.
#[derive(Debug, Clone)]
pub struct TraceGenerator {
    spec: TraceSpec,
    bursts: Vec<(f64, f64, f64)>, // (start, end, multiplier)
}

const DAY_S: f64 = 86_400.0;

impl TraceGenerator {
    pub fn new(spec: TraceSpec) -> Self {
        let mut rng = Pcg::new(spec.seed, 101);
        let fl = spec.dataset.fluctuation;
        let mut bursts = Vec::new();
        if spec.pattern == ArrivalPattern::Fluctuating && fl.bursts_per_hour > 0.0 {
            let expected = fl.bursts_per_hour * spec.duration_s / 3600.0;
            let count = rng.poisson(expected);
            for _ in 0..count {
                let start = rng.range_f64(0.0, spec.duration_s);
                let dur = fl.burst_duration_s * rng.range_f64(0.5, 1.5);
                let mult = 1.0 + (fl.burst_multiplier - 1.0) * rng.range_f64(0.5, 1.5);
                bursts.push((start, start + dur, mult));
            }
            bursts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        }
        TraceGenerator { spec, bursts }
    }

    /// Instantaneous arrival rate at time `t` (requests/s).
    pub fn rate_at(&self, t: f64) -> f64 {
        let fl = self.spec.dataset.fluctuation;
        let mut rate = self.spec.base_rate;
        if self.spec.pattern == ArrivalPattern::Fluctuating {
            // Daily tide: trough at phase 0, peak mid-day.
            let day_t = (t + self.spec.day_phase_s) % DAY_S;
            let tide = 1.0
                + fl.tide_amplitude
                    * (2.0 * std::f64::consts::PI * day_t / DAY_S
                        - std::f64::consts::PI / 2.0)
                        .sin();
            rate *= tide;
            for &(s, e, m) in &self.bursts {
                if t >= s && t < e {
                    rate *= m;
                }
            }
        }
        rate.max(0.0)
    }

    /// Upper bound on the rate over the whole trace (thinning envelope).
    fn rate_bound(&self) -> f64 {
        let fl = self.spec.dataset.fluctuation;
        let max_burst = self
            .bursts
            .iter()
            .map(|b| b.2)
            .fold(1.0f64, |a, b| a.max(b));
        self.spec.base_rate * (1.0 + fl.tide_amplitude) * max_burst
    }

    /// Generate the trace (requests sorted by arrival, ids 0..n).
    pub fn generate(&self) -> Trace {
        let mut rng = Pcg::new(self.spec.seed, 202);
        let mut len_rng = Pcg::new(self.spec.seed, 303);
        let mut requests = Vec::new();
        let mut id = 0u64;
        match self.spec.pattern {
            ArrivalPattern::Fluctuating => {
                let bound = self.rate_bound();
                if bound <= 0.0 {
                    return Trace::default();
                }
                let mut t = 0.0;
                loop {
                    t += rng.exp(bound);
                    if t >= self.spec.duration_s {
                        break;
                    }
                    // Thinning: accept with prob rate(t)/bound.
                    if rng.f64() < self.rate_at(t) / bound {
                        requests.push(self.make_request(id, t, &mut len_rng));
                        id += 1;
                    }
                }
            }
            ArrivalPattern::UniformQps => {
                if self.spec.base_rate <= 0.0 {
                    return Trace::default();
                }
                let gap = 1.0 / self.spec.base_rate;
                let mut t = gap * rng.f64(); // random phase
                while t < self.spec.duration_s {
                    requests.push(self.make_request(id, t, &mut len_rng));
                    id += 1;
                    t += gap;
                }
            }
        }
        Trace::new(requests)
    }

    fn make_request(&self, id: u64, t: f64, len_rng: &mut Pcg) -> Request {
        let prompt = self.spec.dataset.prompt.sample(len_rng);
        let output = self.spec.dataset.output.sample(len_rng);
        match self.spec.prefix {
            PrefixProfile::None | PrefixProfile::Agentic { .. } => {
                Request::new(id, self.spec.class, t, prompt, output)
            }
            PrefixProfile::SharedSystem { prefix_len } => {
                let family = prefix_family(self.spec.seed, 0);
                Request::new(
                    id,
                    self.spec.class,
                    t,
                    prefix_len + prompt,
                    output,
                )
                .with_prefix(family, prefix_len)
            }
            PrefixProfile::FewShot { groups, prefix_len } => {
                let g = len_rng.below(groups.max(1)) as u64;
                Request::new(
                    id,
                    self.spec.class,
                    t,
                    prefix_len + prompt,
                    output,
                )
                .with_prefix(prefix_family(self.spec.seed, g), prefix_len)
            }
        }
    }
}

/// Deterministic family id for `(seed, group)` — distinct across seeds so
/// merged traces never alias prefix content.
fn prefix_family(seed: u64, group: u64) -> u64 {
    crate::prefix::splitmix64(
        seed ^ 0x00c0_ffee ^ group.wrapping_mul(0x9E37_79B9_7F4A_7C15),
    )
}

/// Multi-turn agentic conversations (the heavy-share offline workload):
/// each conversation is a chain of `turns` requests where turn *t*'s
/// prompt is the whole prior context — previous prompt, previous output,
/// and a fresh user message — and is declared fully shareable under the
/// conversation's family. Turn *t* therefore hits the chain turn *t−1*
/// registered and recomputes only the last exchange. Conversations start
/// uniformly over `duration_s`; turns follow after `think_s`-scale gaps.
pub fn agentic_trace(
    ds: DatasetProfile,
    conversations: usize,
    turns: usize,
    think_s: f64,
    duration_s: f64,
    seed: u64,
) -> Trace {
    let mut rng = Pcg::new(seed, 505);
    let mut reqs = Vec::new();
    let mut id = 0u64;
    for c in 0..conversations {
        let family = prefix_family(seed, 0x0a9e_0000 + c as u64);
        let mut t = rng.range_f64(0.0, duration_s.max(1e-9));
        let mut context = ds.prompt.sample(&mut rng);
        for _ in 0..turns {
            let prompt = context.min(16_384);
            let output = ds.output.sample(&mut rng);
            reqs.push(
                Request::new(id, Class::Offline, t, prompt, output)
                    .with_prefix(family, prompt),
            );
            id += 1;
            // The whole exchange joins the next turn's context after a
            // think-time gap.
            context = prompt + output + 32 + rng.below(96);
            t += think_s.max(1e-3) * (0.5 + rng.f64());
        }
    }
    Trace::new(reqs)
}

/// Convenience: synthesize an online trace for a dataset.
pub fn online_trace(
    dataset: DatasetProfile,
    base_rate: f64,
    duration_s: f64,
    seed: u64,
) -> Trace {
    TraceGenerator::new(TraceSpec {
        dataset,
        class: Class::Online,
        pattern: ArrivalPattern::Fluctuating,
        base_rate,
        duration_s,
        day_phase_s: 10.0 * 3600.0, // start near mid-morning ramp
        prefix: PrefixProfile::None,
        seed,
    })
    .generate()
}

/// Two-phase regime-change workload (a compressed tide edge, used by the
/// elastic pool-manager tests and `bench_elastic_pools`): online at
/// `hi_rate` base for the first half and `lo_rate` base for the second,
/// plus uniform-QPS offline load throughout. Base rates are multiplied by
/// the dataset's daily tide — [`online_trace`] starts traces at the
/// mid-morning ramp, a factor of ≈ 1.4 for `azure-conv`.
pub fn two_phase_trace(
    online_ds: DatasetProfile,
    hi_rate: f64,
    lo_rate: f64,
    half_s: f64,
    offline_ds: DatasetProfile,
    offline_qps: f64,
    seed: u64,
) -> Trace {
    let hi = online_trace(online_ds.clone(), hi_rate, half_s, seed);
    let mut lo = online_trace(online_ds, lo_rate, half_s, seed + 1);
    for r in &mut lo.requests {
        r.arrival += half_s;
    }
    let mut trace = hi.merge(lo);
    if offline_qps > 0.0 {
        trace = trace.merge(offline_trace(
            offline_ds,
            offline_qps,
            2.0 * half_s,
            seed + 2,
        ));
    }
    trace
}

/// Convenience: uniform-QPS offline trace (the §5.2 offline load control).
pub fn offline_trace(
    dataset: DatasetProfile,
    qps: f64,
    duration_s: f64,
    seed: u64,
) -> Trace {
    offline_trace_with_prefix(
        dataset,
        qps,
        duration_s,
        PrefixProfile::None,
        seed,
    )
}

/// [`offline_trace`] with a shared-prefix workload family (§3.7). An
/// [`PrefixProfile::Agentic`] profile delegates to [`agentic_trace`] with
/// think time set so the requested QPS is met in expectation.
pub fn offline_trace_with_prefix(
    dataset: DatasetProfile,
    qps: f64,
    duration_s: f64,
    prefix: PrefixProfile,
    seed: u64,
) -> Trace {
    if let PrefixProfile::Agentic { conversations, turns } = prefix {
        // conversations × turns requests over the duration ≈ qps·duration:
        // scale the conversation count to the requested volume and spread
        // turns across roughly half the window.
        let want = (qps * duration_s).round().max(1.0) as usize;
        let convs = want.div_ceil(turns.max(1)).max(conversations.min(want));
        let think = (duration_s / (2.0 * turns.max(1) as f64)).max(1e-3);
        return agentic_trace(dataset, convs, turns, think, duration_s, seed);
    }
    TraceGenerator::new(TraceSpec {
        dataset,
        class: Class::Offline,
        pattern: ArrivalPattern::UniformQps,
        base_rate: qps,
        duration_s,
        day_phase_s: 0.0,
        prefix,
        seed,
    })
    .generate()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_phase_trace_shifts_and_merges() {
        let t = two_phase_trace(
            DatasetProfile::azure_conv(),
            4.0,
            0.5,
            100.0,
            DatasetProfile::ooc_offline(),
            1.0,
            7,
        );
        // Sorted, dense ids, both classes present.
        assert!(t.requests.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert!(t.requests.iter().enumerate().all(|(i, r)| r.id == i as u64));
        assert!(t.count_class(Class::Offline) > 50);
        let first_half = t
            .requests
            .iter()
            .filter(|r| r.class == Class::Online && r.arrival < 100.0)
            .count();
        let second_half =
            t.count_class(Class::Online).saturating_sub(first_half);
        assert!(
            first_half > 3 * second_half,
            "hi phase {first_half} vs lo phase {second_half}"
        );
        assert!(t.duration() <= 200.0);
    }

    fn gen(base_rate: f64, duration: f64, seed: u64) -> TraceGenerator {
        TraceGenerator::new(TraceSpec {
            dataset: DatasetProfile::ooc_online(),
            class: Class::Online,
            pattern: ArrivalPattern::Fluctuating,
            base_rate,
            duration_s: duration,
            day_phase_s: 0.0,
            prefix: PrefixProfile::None,
            seed,
        })
    }

    #[test]
    fn deterministic_per_seed() {
        let a = gen(2.0, 600.0, 7).generate();
        let b = gen(2.0, 600.0, 7).generate();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.prompt_len, y.prompt_len);
        }
        let c = gen(2.0, 600.0, 8).generate();
        assert_ne!(a.len(), c.len());
    }

    #[test]
    fn mean_rate_close_to_base() {
        // Over a full day the tide averages out to ~base rate; bursts add a
        // small positive bias. Check within tolerance on a half-day window.
        let g = gen(1.0, 43_200.0, 3);
        let t = g.generate();
        let rate = t.len() as f64 / 43_200.0;
        assert!((0.5..2.0).contains(&rate), "rate {rate}");
    }

    #[test]
    fn rate_function_respects_bound() {
        let g = gen(2.0, 7200.0, 11);
        let bound = g.rate_bound();
        for i in 0..1000 {
            let t = i as f64 * 7.2;
            assert!(g.rate_at(t) <= bound + 1e-9);
        }
    }

    #[test]
    fn bursts_create_visible_spikes() {
        // With strong bursts, the max minute-bucket should clearly exceed
        // the median minute-bucket (Fig. 1's bursty spikes).
        let t = online_trace(DatasetProfile::azure_code(), 3.0, 7200.0, 5);
        let series = t.rate_series(60.0);
        let mut sorted: Vec<usize> = series.clone();
        sorted.sort_unstable();
        let median = sorted[sorted.len() / 2] as f64;
        let max = *sorted.last().unwrap() as f64;
        assert!(max > 1.8 * median.max(1.0), "median {median} max {max}");
    }

    #[test]
    fn tide_shape_visible_over_a_day() {
        // Compare trough-quarter vs peak-quarter volumes over one day.
        let g = gen(1.0, DAY_S, 13);
        let t = g.generate();
        let q = (DAY_S / 4.0) as usize;
        let series = t.rate_series(1.0);
        let sum = |a: usize, b: usize| -> usize {
            series[a.min(series.len())..b.min(series.len())].iter().sum()
        };
        let q1 = sum(0, q); // starts at trough (phase 0)
        let q3 = sum(2 * q, 3 * q); // mid-day peak
        assert!(
            q3 as f64 > 1.5 * q1 as f64,
            "trough {q1} vs peak {q3}"
        );
    }

    #[test]
    fn uniform_qps_spacing() {
        let t = offline_trace(DatasetProfile::ooc_offline(), 2.0, 100.0, 1);
        assert!((t.len() as i64 - 200).abs() <= 2, "n {}", t.len());
        for w in t.requests.windows(2) {
            let gap = w[1].arrival - w[0].arrival;
            assert!((gap - 0.5).abs() < 1e-9, "gap {gap}");
        }
        assert!(t.requests.iter().all(|r| r.class == Class::Offline));
    }

    #[test]
    fn zero_rate_empty() {
        assert!(offline_trace(DatasetProfile::ooc_offline(), 0.0, 100.0, 1)
            .is_empty());
    }

    #[test]
    fn prefix_profile_parse_display_roundtrip() {
        for p in [
            PrefixProfile::None,
            PrefixProfile::DEFAULT_SHARED,
            PrefixProfile::DEFAULT_FEW_SHOT,
            PrefixProfile::DEFAULT_AGENTIC,
            PrefixProfile::SharedSystem { prefix_len: 2048 },
            PrefixProfile::FewShot { groups: 4, prefix_len: 512 },
            PrefixProfile::Agentic { conversations: 32, turns: 8 },
        ] {
            assert_eq!(p.to_string().parse::<PrefixProfile>().unwrap(), p);
        }
        assert_eq!(
            "shared-system".parse::<PrefixProfile>().unwrap(),
            PrefixProfile::DEFAULT_SHARED
        );
        assert!("prefixy".parse::<PrefixProfile>().is_err());
        assert!("shared-system(len=0)".parse::<PrefixProfile>().is_err());
        assert!("few-shot(flavors=2)".parse::<PrefixProfile>().is_err());
        assert!("agentic(turns=0)".parse::<PrefixProfile>().is_err());
    }

    #[test]
    fn shared_system_prefixes_every_request() {
        let t = offline_trace_with_prefix(
            DatasetProfile::ooc_offline(),
            2.0,
            100.0,
            PrefixProfile::SharedSystem { prefix_len: 777 },
            3,
        );
        assert!(t.len() > 100);
        let fam = t.requests[0].prefix.unwrap().family;
        for r in &t.requests {
            let p = r.prefix.unwrap();
            assert_eq!(p.family, fam, "one shared system prompt");
            assert_eq!(p.len, 777);
            assert!(r.prompt_len >= 777, "prefix prepended to the prompt");
        }
    }

    #[test]
    fn few_shot_groups_bound_family_count() {
        let t = offline_trace_with_prefix(
            DatasetProfile::ooc_offline(),
            2.0,
            200.0,
            PrefixProfile::FewShot { groups: 4, prefix_len: 300 },
            5,
        );
        let mut fams: Vec<u64> =
            t.requests.iter().map(|r| r.prefix.unwrap().family).collect();
        fams.sort_unstable();
        fams.dedup();
        assert!(
            (2..=4).contains(&fams.len()),
            "expected ≤4 template families, got {}",
            fams.len()
        );
    }

    #[test]
    fn agentic_contexts_grow_and_nest() {
        let t = agentic_trace(
            DatasetProfile::azure_conv(),
            6,
            5,
            10.0,
            300.0,
            9,
        );
        assert_eq!(t.len(), 30);
        // Group by family: each conversation's prompts strictly grow and
        // each turn declares its whole prompt shareable.
        use std::collections::HashMap;
        let mut convs: HashMap<u64, Vec<&Request>> = HashMap::new();
        for r in &t.requests {
            let p = r.prefix.unwrap();
            assert_eq!(p.len, r.prompt_len);
            convs.entry(p.family).or_default().push(r);
        }
        assert_eq!(convs.len(), 6);
        for turns in convs.values_mut() {
            turns.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
            assert_eq!(turns.len(), 5);
            for w in turns.windows(2) {
                assert!(
                    w[1].prompt_len > w[0].prompt_len
                        || w[1].prompt_len == 16_384, // context cap reached
                    "context must grow turn over turn"
                );
            }
        }
    }

    #[test]
    fn prompt_profile_parse_display_json_roundtrip() {
        for p in [
            PromptProfile::Dataset,
            PromptProfile::DEFAULT_LONG,
            PromptProfile::LongPrompt {
                mean: 12000,
                sigma: 0.8,
                max: 16384,
            },
        ] {
            assert_eq!(p.to_string().parse::<PromptProfile>().unwrap(), p);
            assert_eq!(PromptProfile::from_json(&p.to_json()).unwrap(), p);
        }
        assert_eq!(
            "long-prompt".parse::<PromptProfile>().unwrap(),
            PromptProfile::DEFAULT_LONG
        );
        assert_eq!(
            "long-prompt(mean=9000)".parse::<PromptProfile>().unwrap(),
            PromptProfile::LongPrompt {
                mean: 9000,
                sigma: 1.2,
                max: 16384
            }
        );
        // A max below the 64-token floor must not panic at sample time.
        let tiny = "long-prompt(mean=40,max=50)"
            .parse::<PromptProfile>()
            .unwrap()
            .apply(&DatasetProfile::ooc_offline());
        assert!(tiny.prompt.min <= tiny.prompt.max);
        let mut rng = Pcg::seeded(3);
        assert!(tiny.prompt.sample(&mut rng) <= 50);
        assert!("short-prompt".parse::<PromptProfile>().is_err());
        assert!("long-prompt(mean=0)".parse::<PromptProfile>().is_err());
        assert!("long-prompt(mean=9,max=8)".parse::<PromptProfile>().is_err());
        assert!("long-prompt(warp=2)".parse::<PromptProfile>().is_err());
    }

    #[test]
    fn long_prompt_profile_shifts_the_tail() {
        let base = DatasetProfile::ooc_offline();
        let long = PromptProfile::DEFAULT_LONG.apply(&base);
        assert_eq!(long.prompt.mean, 6000.0);
        assert_eq!(long.prompt.max, 16384);
        // Outputs and arrival shape untouched.
        assert_eq!(long.output, base.output);
        // Sampled prompts are markedly longer than the base profile's.
        let t_base = offline_trace(base, 2.0, 200.0, 11);
        let t_long = offline_trace(long, 2.0, 200.0, 11);
        let mean = |t: &crate::trace::Trace| {
            t.requests.iter().map(|r| r.prompt_len as f64).sum::<f64>()
                / t.len().max(1) as f64
        };
        assert!(
            mean(&t_long) > 2.0 * mean(&t_base),
            "long {} vs base {}",
            mean(&t_long),
            mean(&t_base)
        );
        // Dataset profile is the identity.
        assert_eq!(PromptProfile::Dataset.apply(&DatasetProfile::azure_conv()).prompt,
            DatasetProfile::azure_conv().prompt);
    }

    #[test]
    fn lengths_match_profile_means() {
        let t = online_trace(DatasetProfile::azure_conv(), 5.0, 7200.0, 9);
        let (p, o) = t.mean_lengths(None);
        assert!((p / 1512.30 - 1.0).abs() < 0.15, "prompt mean {p}");
        assert!((o / 98.75 - 1.0).abs() < 0.15, "output mean {o}");
    }
}
