//! Trace scaling — the paper's §5.1.3 procedure, verbatim:
//!
//! - scale **down** by randomly dropping requests at a fixed ratio;
//! - scale **up** by replicating existing request prompt/output lengths
//!   while *interpolating* their timestamps between neighbors.
//!
//! Both transforms only change the aggregate rate: a 5-minute spike stays a
//! 5-minute spike, and the peak/trough ratio is preserved.

use crate::request::Request;
use crate::util::rng::Pcg;

use super::Trace;

/// Scale a trace's aggregate request rate by `factor` (> 0).
///
/// `factor < 1` drops requests uniformly at random; `factor > 1` first
/// applies the integer part by replication+interpolation, then the
/// fractional remainder by another replication pass at the leftover ratio.
pub fn scale_trace(trace: &Trace, factor: f64, seed: u64) -> Trace {
    assert!(factor > 0.0, "scale factor must be positive");
    let mut rng = Pcg::new(seed, 404);
    if trace.is_empty() {
        return Trace::default();
    }
    if (factor - 1.0).abs() < 1e-12 {
        return relabel(trace.requests.clone());
    }
    if factor < 1.0 {
        let kept: Vec<Request> = trace
            .requests
            .iter()
            .filter(|_| rng.chance(factor))
            .cloned()
            .collect();
        return relabel(kept);
    }
    // Scale up: keep originals, add (factor - 1) replicas in expectation.
    let mut out = trace.requests.clone();
    let extra = factor - 1.0;
    let whole = extra.floor() as usize;
    let frac = extra - whole as f64;
    for (i, r) in trace.requests.iter().enumerate() {
        let copies = whole + if rng.chance(frac) { 1 } else { 0 };
        for _ in 0..copies {
            let mut c = r.clone();
            // Interpolate the timestamp toward the next arrival so replicas
            // land inside the same local traffic regime.
            let next = trace
                .requests
                .get(i + 1)
                .map(|n| n.arrival)
                .unwrap_or(r.arrival);
            c.arrival = r.arrival + (next - r.arrival) * rng.f64();
            // Donor lengths are reused verbatim (paper: "replicating
            // existing request prompt and output lengths").
            out.push(c);
        }
    }
    relabel(out)
}

fn relabel(mut requests: Vec<Request>) -> Trace {
    requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    for (i, r) in requests.iter_mut().enumerate() {
        r.id = i as u64;
    }
    Trace { requests }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::Class;
    use crate::trace::datasets::DatasetProfile;
    use crate::trace::generator::online_trace;

    fn base() -> Trace {
        online_trace(DatasetProfile::ooc_online(), 2.0, 7200.0, 42)
    }

    #[test]
    fn downscale_rate() {
        let t = base();
        let s = scale_trace(&t, 0.5, 1);
        let ratio = s.len() as f64 / t.len() as f64;
        assert!((ratio - 0.5).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn upscale_rate_integer_and_fraction() {
        let t = base();
        for factor in [2.0, 2.5, 3.75] {
            let s = scale_trace(&t, factor, 2);
            let ratio = s.len() as f64 / t.len() as f64;
            assert!((ratio / factor - 1.0).abs() < 0.06, "f {factor} r {ratio}");
        }
    }

    #[test]
    fn identity_scale() {
        let t = base();
        let s = scale_trace(&t, 1.0, 3);
        assert_eq!(s.len(), t.len());
    }

    #[test]
    fn temporal_pattern_preserved() {
        // The minute-bucket correlation between original and 3x-scaled trace
        // must be high: spikes stay where they were.
        let t = base();
        let s = scale_trace(&t, 3.0, 4);
        let a = t.rate_series(60.0);
        let b = s.rate_series(60.0);
        let n = a.len().min(b.len());
        let (a, b) = (&a[..n], &b[..n]);
        let ma = a.iter().sum::<usize>() as f64 / n as f64;
        let mb = b.iter().sum::<usize>() as f64 / n as f64;
        let mut cov = 0.0;
        let mut va = 0.0;
        let mut vb = 0.0;
        for i in 0..n {
            let da = a[i] as f64 - ma;
            let db = b[i] as f64 - mb;
            cov += da * db;
            va += da * da;
            vb += db * db;
        }
        let corr = cov / (va.sqrt() * vb.sqrt());
        assert!(corr > 0.75, "corr {corr}");
    }

    #[test]
    fn peak_trough_ratio_roughly_preserved() {
        let t = online_trace(DatasetProfile::ooc_online(), 4.0, 86_400.0, 7);
        let s = scale_trace(&t, 2.0, 8);
        let ratio = |tr: &Trace| {
            let series = tr.rate_series(3600.0);
            let max = *series.iter().max().unwrap() as f64;
            let min = *series.iter().min().unwrap() as f64;
            max / min.max(1.0)
        };
        let r0 = ratio(&t);
        let r1 = ratio(&s);
        assert!((r1 / r0 - 1.0).abs() < 0.5, "r0 {r0} r1 {r1}");
    }

    #[test]
    fn replicas_reuse_donor_lengths() {
        let t = base();
        let s = scale_trace(&t, 2.0, 9);
        use std::collections::HashSet;
        let originals: HashSet<(usize, usize)> = t
            .requests
            .iter()
            .map(|r| (r.prompt_len, r.output_len))
            .collect();
        for r in &s.requests {
            assert!(originals.contains(&(r.prompt_len, r.output_len)));
        }
    }

    #[test]
    fn ids_unique_and_sorted() {
        let s = scale_trace(&base(), 2.5, 10);
        for (i, r) in s.requests.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
        assert!(s.requests.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert!(s.requests.iter().all(|r| r.class == Class::Online));
    }

    #[test]
    fn empty_trace() {
        let e = scale_trace(&Trace::default(), 2.0, 1);
        assert!(e.is_empty());
    }
}
