//! Minimal property-testing harness (proptest is not in the offline vendor
//! set). Runs a closure over N seeded-random cases and, on failure, retries
//! with progressively "smaller" seeds-derived cases is not possible
//! generically — instead it reports the failing seed so the case replays
//! deterministically:
//!
//! ```ignore
//! forall(100, |rng| {
//!     let n = rng.below(50) + 1;
//!     /* build case, return Err(msg) to fail */
//!     Ok(())
//! });
//! ```

use crate::util::rng::Pcg;

/// Run `prop` over `cases` seeded RNGs; panic with the failing seed on the
/// first counterexample.
pub fn forall<F>(cases: usize, prop: F)
where
    F: FnMut(&mut Pcg) -> Result<(), String>,
{
    forall_seeded(0xabcdef, cases, prop)
}

/// Like [`forall`] with an explicit base seed (use the seed printed by a
/// failure to replay it).
pub fn forall_seeded<F>(base_seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Pcg) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Pcg::new(seed, 7777);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed at case {case} (replay with forall_seeded({seed}, 1, ..)): {msg}"
            );
        }
    }
}

/// Assert helper returning Err instead of panicking, for use inside props.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        forall(50, |rng| {
            let a = rng.below(100);
            let b = rng.below(100);
            prop_assert!(a + b == b + a, "commutativity {a} {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(50, |rng| {
            let v = rng.below(10);
            prop_assert!(v < 9, "hit v={v}");
            Ok(())
        });
    }

    #[test]
    fn deterministic_replay() {
        // Same base seed -> same sequence of cases.
        let mut log1 = Vec::new();
        forall_seeded(99, 5, |rng| {
            log1.push(rng.next_u64());
            Ok(())
        });
        let mut log2 = Vec::new();
        forall_seeded(99, 5, |rng| {
            log2.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(log1, log2);
    }
}
