//! Elastic pool manager (DESIGN.md §3.6): predictive repartitioning of the
//! strict/relaxed instance pools.
//!
//! OOCO's latency-constraint split absorbs P/D imbalance *within* a fixed
//! pool boundary; a sustained shift in the online/offline mix (a diurnal
//! tide, a workload regime change) strands capacity on the wrong side of
//! it. This subsystem sits **above** [`crate::scheduler::SchedulerCore`]'s
//! per-step decisions and re-plans the boundary itself at coarse
//! granularity — the scheduler handles bursts, the pool manager handles
//! tides:
//!
//! - [`LoadEstimator`] (estimator.rs) — EWMA + burst-corrected arrival
//!   rates and request shapes per class, fed from the arrival stream;
//! - [`min_strict_pool`] (planner.rs) — Roofline-guided capacity planning:
//!   the minimum strict pool meeting the TPOT SLO at the estimated load,
//!   headroom-parameterized;
//! - [`Transition`] (transition.rs) — the drain → flip → warm state
//!   machine a repurposed instance walks through, never violating online
//!   SLOs mid-transition.
//!
//! [`PoolManager`] ties the three together and owns the plan/transition
//! bookkeeping. It is *state inside the core* — decisions surface as
//! [`crate::scheduler::Action::RepartitionPlan`] and
//! [`crate::scheduler::Action::RoleChange`] entries of the substrate-
//! independent action stream, so the plan timeline is differential-tested
//! like every other scheduling decision. Per-epoch pool sizes, transition
//! durations, and stranded capacity land in [`crate::metrics::PoolReport`].

pub mod estimator;
pub mod planner;
pub mod transition;

pub use estimator::{ClassLoad, LoadEstimator};
pub use planner::{
    max_slo_batch, max_slo_batch_chunked, max_slo_batch_shared,
    min_strict_pool, pressure_with_capacity, strict_pressure, PlannerInput,
};
pub use transition::{Transition, TransitionPhase, WARMUP_S};

use crate::config::{PoolPolicy, SloSpec};
use crate::metrics::{PoolEpoch, PoolReport};
use crate::perfmodel::PerfModel;
use crate::request::Class;
use crate::util::stats::LatencySummary;

/// Minimum interval between `Reactive` trigger evaluations (s) — bounds
/// plan-evaluation churn on the event-dense decode path.
const REACTIVE_CHECK_S: f64 = 1.0;

/// Smallest accepted `Periodic` epoch (s). `FromStr` rejects non-positive
/// epochs, but `PoolPolicy` has public fields — clamping here keeps a
/// struct-literal `epoch_s: 0.0` from spinning the epoch catch-up loop
/// forever.
const MIN_EPOCH_S: f64 = 1e-3;

/// EWMA smoothing weight of the prefix-cache hit-share estimate fed to the
/// planner's cache-adjusted KV footprint (DESIGN.md §3.7).
const SHARE_ALPHA: f64 = 0.05;

/// One repartition decision, returned by [`PoolManager::replan`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolPlan {
    /// Monotone plan counter.
    pub epoch: u64,
    pub strict_target: usize,
    pub relaxed_target: usize,
}

/// The elastic pool manager: load estimation, capacity planning, and
/// role-transition bookkeeping above the per-step scheduler.
#[derive(Debug, Clone)]
pub struct PoolManager {
    pub policy: PoolPolicy,
    pub estimator: LoadEstimator,
    /// The in-flight role transition, if any (at most one at a time; the
    /// core owns the drain/flip mechanics).
    pub transition: Option<Transition>,
    next_epoch_at: f64,
    next_check_at: f64,
    cooldown_until: f64,
    /// EWMA fraction of admitted prompt tokens served from the prefix
    /// cache — the planner's cache-adjusted load signal.
    prefix_share: f64,
    /// Prefill-chunk reserve of the composed iteration model (DESIGN.md
    /// §3.8), set by the core from the configured `chunk_tokens`: the
    /// planner sizes for composed iterations, not pure-decode ones.
    chunk_reserve: usize,
    // ---- metrics ----
    epochs: Vec<PoolEpoch>,
    transition_s: LatencySummary,
    plans: u64,
    flips: u64,
    stranded_acc: f64,
    stranded_last_t: f64,
    planned_strict: Option<usize>,
}

impl PoolManager {
    pub fn new(policy: PoolPolicy) -> Self {
        let next_epoch_at = match policy {
            PoolPolicy::Periodic { epoch_s, .. } => epoch_s.max(MIN_EPOCH_S),
            _ => f64::INFINITY,
        };
        PoolManager {
            policy,
            estimator: LoadEstimator::default_taus(),
            transition: None,
            next_epoch_at,
            next_check_at: 0.0,
            cooldown_until: 0.0,
            prefix_share: 0.0,
            chunk_reserve: 0,
            epochs: Vec::new(),
            transition_s: LatencySummary::new(),
            plans: 0,
            flips: 0,
            stranded_acc: 0.0,
            stranded_last_t: 0.0,
            planned_strict: None,
        }
    }

    /// Feed one arrival into the load estimator.
    pub fn observe_arrival(
        &mut self,
        now: f64,
        class: Class,
        prompt: usize,
        output: usize,
    ) {
        if self.policy.is_elastic() {
            self.estimator.observe_arrival(now, class, prompt, output);
        }
    }

    /// Feed one prefill admission's cache outcome (`cached` of `total`
    /// prompt tokens served from the prefix cache) into the share EWMA the
    /// planner consumes. Work the cache absorbs must not inflate the plan.
    pub fn observe_prefix(&mut self, cached: usize, total: usize) {
        if !self.policy.is_elastic() || total == 0 {
            return;
        }
        let x = (cached as f64 / total as f64).clamp(0.0, 1.0);
        self.prefix_share += SHARE_ALPHA * (x - self.prefix_share);
    }

    /// Current cache-share estimate (exposed for tests/metrics).
    pub fn prefix_share(&self) -> f64 {
        self.prefix_share
    }

    /// Set the chunk-token reserve the planner prices into every composed
    /// iteration (0 = exclusive-step sizing).
    pub fn set_chunk_reserve(&mut self, tokens: usize) {
        self.chunk_reserve = tokens;
    }

    /// Current chunk-token reserve (exposed for tests).
    pub fn chunk_reserve(&self) -> usize {
        self.chunk_reserve
    }

    /// Compute a repartition plan if one is due at `now` (Periodic epoch
    /// boundary crossed, or Reactive thresholds tripped outside the
    /// cooldown). Returns `None` when nothing is due — including always,
    /// under `Static`.
    pub fn replan(
        &mut self,
        now: f64,
        pm: &PerfModel,
        slo: &SloSpec,
        n_relaxed: usize,
        n_strict: usize,
    ) -> Option<PoolPlan> {
        let total = n_relaxed + n_strict;
        match self.policy {
            PoolPolicy::Static => None,
            PoolPolicy::Periodic { epoch_s, headroom } => {
                if now < self.next_epoch_at {
                    return None;
                }
                let epoch_s = epoch_s.max(MIN_EPOCH_S);
                while self.next_epoch_at <= now {
                    self.next_epoch_at += epoch_s;
                }
                let online = self.estimator.online(now);
                let mut load = PlannerInput::from_load(&online);
                load.shared_kv_fraction = self.prefix_share;
                load.chunk_prefill_tokens = self.chunk_reserve;
                let target = min_strict_pool(pm, slo, &load, total, headroom)
                    .clamp(1, total.saturating_sub(1).max(1));
                let rates = (online.rate, self.estimator.offline(now).rate);
                Some(self.record_plan(now, n_relaxed, n_strict, target, rates))
            }
            PoolPolicy::Reactive { up, down, cooldown_s } => {
                if now < self.next_check_at {
                    return None;
                }
                self.next_check_at = now + REACTIVE_CHECK_S;
                if now < self.cooldown_until {
                    return None;
                }
                let online = self.estimator.online(now);
                let mut load = PlannerInput::from_load(&online);
                load.shared_kv_fraction = self.prefix_share;
                load.chunk_prefill_tokens = self.chunk_reserve;
                // One roofline capacity probe serves both threshold
                // checks (`strict_pressure` would rerun its binary search
                // per call; per-instance capacity does not depend on n).
                let concurrent = load.concurrent_decodes(slo.tpot);
                let per_inst = max_slo_batch_chunked(
                    pm,
                    load.mean_kv(),
                    slo.tpot,
                    load.shared_kv_fraction,
                    load.chunk_prefill_tokens,
                );
                let pressure =
                    |n: usize| pressure_with_capacity(concurrent, per_inst, n);
                let target = if pressure(n_strict) > up && n_relaxed > 1 {
                    n_strict + 1
                } else if n_strict > 1 && pressure(n_strict - 1) < down {
                    n_strict - 1
                } else {
                    n_strict
                };
                if target == n_strict {
                    return None;
                }
                self.cooldown_until = now + cooldown_s;
                let rates = (online.rate, self.estimator.offline(now).rate);
                Some(self.record_plan(now, n_relaxed, n_strict, target, rates))
            }
        }
    }

    fn record_plan(
        &mut self,
        now: f64,
        n_relaxed: usize,
        n_strict: usize,
        target: usize,
        (est_online_rate, est_offline_rate): (f64, f64),
    ) -> PoolPlan {
        self.accrue_stranded(now, n_strict);
        self.planned_strict = Some(target);
        // `plans` doubles as the monotone epoch counter of PoolPlan.
        self.plans += 1;
        self.epochs.push(PoolEpoch {
            at: now,
            relaxed: n_relaxed,
            strict: n_strict,
            planned_strict: target,
            est_online_rate,
            est_offline_rate,
        });
        PoolPlan {
            epoch: self.plans,
            strict_target: target,
            relaxed_target: n_relaxed + n_strict - target,
        }
    }

    /// Integrate stranded capacity up to `now` at the pre-change strict
    /// size, then move the integration cursor.
    fn accrue_stranded(&mut self, now: f64, n_strict: usize) {
        if let Some(p) = self.planned_strict {
            self.stranded_acc += (now - self.stranded_last_t).max(0.0)
                * n_strict.abs_diff(p) as f64;
        }
        self.stranded_last_t = now;
    }

    /// A role flip completed (`strict_before` = strict-pool size *before*
    /// the flip, for the stranded-capacity integral).
    pub fn on_flip(&mut self, now: f64, strict_before: usize) {
        self.accrue_stranded(now, strict_before);
        self.flips += 1;
    }

    /// The warm step of the in-flight transition finished: the transition
    /// is complete, record its drain-to-warm duration.
    pub fn on_warm_done(&mut self, now: f64) {
        if let Some(t) = self.transition.take() {
            self.transition_s.record((now - t.started).max(0.0));
        }
    }

    /// Drop the in-flight transition without completing it (fleet fault
    /// model, DESIGN.md §3.9: the transitioning instance crashed). No
    /// duration is recorded; the next replan starts fresh.
    pub fn abort_transition(&mut self) {
        self.transition = None;
    }

    /// Snapshot the pool-manager metrics at `now`.
    pub fn report(
        &self,
        now: f64,
        n_relaxed: usize,
        n_strict: usize,
    ) -> PoolReport {
        let mut stranded = self.stranded_acc;
        if let Some(p) = self.planned_strict {
            stranded += (now - self.stranded_last_t).max(0.0)
                * n_strict.abs_diff(p) as f64;
        }
        PoolReport {
            policy: self.policy.to_string(),
            plans: self.plans,
            flips: self.flips,
            epochs: self.epochs.clone(),
            transition_s: self.transition_s.summary(),
            stranded_instance_s: stranded,
            final_relaxed: n_relaxed,
            final_strict: n_strict,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;
    use crate::instance::PoolRole;

    fn setup() -> (PerfModel, SloSpec) {
        let cfg = ServingConfig::preset_7b();
        (PerfModel::new(cfg.model, cfg.hardware), cfg.slo)
    }

    fn feed(pm: &mut PoolManager, rate: f64, t0: f64, t1: f64) {
        let dt = 1.0 / rate;
        let mut t = t0;
        while t < t1 {
            pm.observe_arrival(t, Class::Online, 1500, 100);
            t += dt;
        }
    }

    #[test]
    fn static_policy_never_plans() {
        let (perf, slo) = setup();
        let mut mgr = PoolManager::new(PoolPolicy::Static);
        feed(&mut mgr, 5.0, 0.0, 100.0);
        assert!(mgr.replan(1000.0, &perf, &slo, 2, 2).is_none());
        assert_eq!(mgr.report(1000.0, 2, 2).plans, 0);
    }

    #[test]
    fn periodic_plans_on_epoch_boundaries_only() {
        let (perf, slo) = setup();
        let mut mgr = PoolManager::new(PoolPolicy::Periodic {
            epoch_s: 60.0,
            headroom: 0.15,
        });
        feed(&mut mgr, 1.0, 0.0, 59.0);
        assert!(mgr.replan(59.0, &perf, &slo, 2, 2).is_none());
        let plan = mgr.replan(61.0, &perf, &slo, 2, 2).expect("epoch due");
        assert_eq!(plan.strict_target + plan.relaxed_target, 4);
        assert!(plan.strict_target >= 1 && plan.strict_target <= 3);
        // Same epoch is not re-planned.
        assert!(mgr.replan(61.5, &perf, &slo, 2, 2).is_none());
        let rep = mgr.report(61.5, 2, 2);
        assert_eq!(rep.plans, 1);
        assert_eq!(rep.epochs.len(), 1);
    }

    #[test]
    fn zero_epoch_struct_literal_does_not_hang() {
        let (perf, slo) = setup();
        let mut mgr = PoolManager::new(PoolPolicy::Periodic {
            epoch_s: 0.0,
            headroom: 0.15,
        });
        feed(&mut mgr, 1.0, 0.0, 5.0);
        // Must terminate (clamped epoch) and produce a plan.
        assert!(mgr.replan(5.0, &perf, &slo, 2, 2).is_some());
    }

    #[test]
    fn reactive_respects_cooldown_and_thresholds() {
        let (perf, slo) = setup();
        let mut mgr = PoolManager::new(PoolPolicy::Reactive {
            up: 0.85,
            down: 0.5,
            cooldown_s: 30.0,
        });
        // Massive online load: pressure far above `up`.
        feed(&mut mgr, 150.0, 0.0, 120.0);
        let plan = mgr
            .replan(120.0, &perf, &slo, 3, 1)
            .expect("overload must trigger growth");
        assert_eq!(plan.strict_target, 2);
        // Cooldown suppresses the immediate follow-up.
        assert!(mgr.replan(121.5, &perf, &slo, 3, 1).is_none());
        // After the cooldown it may move again.
        assert!(mgr.replan(151.0, &perf, &slo, 2, 2).is_some());
    }

    #[test]
    fn reactive_shrinks_an_idle_overprovisioned_pool() {
        let (perf, slo) = setup();
        let mut mgr = PoolManager::new(PoolPolicy::DEFAULT_REACTIVE);
        // Trickle load, huge strict pool.
        feed(&mut mgr, 0.1, 0.0, 100.0);
        let plan = mgr
            .replan(100.0, &perf, &slo, 1, 4)
            .expect("idle overprovision must trigger shrink");
        assert_eq!(plan.strict_target, 3);
    }

    #[test]
    fn chunk_reserve_flows_into_periodic_plans() {
        // With a chunk reserve set (a substrate fusing prefill into
        // SLO-bounded iterations — DESIGN.md §3.8), the planner prices
        // composed iterations and can only ask for an equal-or-larger
        // strict pool than the pure-decode sizing.
        let (perf, slo) = setup();
        let policy = PoolPolicy::Periodic {
            epoch_s: 60.0,
            headroom: 0.15,
        };
        let run = |reserve: usize| {
            let mut mgr = PoolManager::new(policy);
            assert_eq!(mgr.chunk_reserve(), 0);
            mgr.set_chunk_reserve(reserve);
            assert_eq!(mgr.chunk_reserve(), reserve);
            feed(&mut mgr, 40.0, 0.0, 60.0);
            mgr.replan(61.0, &perf, &slo, 6, 2)
                .expect("epoch due")
                .strict_target
        };
        let pure = run(0);
        let composed = run(4096);
        assert!(
            composed >= pure,
            "chunk reserve shrank the plan: {pure} -> {composed}"
        );
    }

    #[test]
    fn prefix_share_tracks_admissions_when_elastic() {
        let mut mgr = PoolManager::new(PoolPolicy::DEFAULT_PERIODIC);
        assert_eq!(mgr.prefix_share(), 0.0);
        for _ in 0..200 {
            mgr.observe_prefix(60, 100);
        }
        assert!(
            (mgr.prefix_share() - 0.6).abs() < 0.05,
            "share {}",
            mgr.prefix_share()
        );
        mgr.observe_prefix(0, 0); // no-op, not a division by zero
        // Static pools ignore the signal entirely.
        let mut st = PoolManager::new(PoolPolicy::Static);
        st.observe_prefix(60, 100);
        assert_eq!(st.prefix_share(), 0.0);
    }

    #[test]
    fn stranded_capacity_integrates_plan_gap() {
        let (perf, slo) = setup();
        let mut mgr = PoolManager::new(PoolPolicy::Periodic {
            epoch_s: 10.0,
            headroom: 0.15,
        });
        // Load that wants more than one strict instance.
        feed(&mut mgr, 300.0, 0.0, 20.0);
        let plan = mgr.replan(20.0, &perf, &slo, 3, 1).expect("due");
        assert!(plan.strict_target > 1, "target {}", plan.strict_target);
        let gap = (plan.strict_target - 1) as f64;
        // 5 s at the wrong split before any flip.
        let rep = mgr.report(25.0, 3, 1);
        assert!((rep.stranded_instance_s - 5.0 * gap).abs() < 1e-9);
        // A flip toward the plan shrinks the per-second gap.
        mgr.transition =
            Some(Transition::drain(PoolRole::Relaxed, 2, 25.0));
        mgr.on_flip(26.0, 1);
        mgr.on_warm_done(27.0);
        let rep = mgr.report(27.0, 2, 2);
        assert_eq!(rep.flips, 1);
        assert_eq!(rep.transition_s.count, 1);
        assert!((rep.transition_s.mean - 2.0).abs() < 1e-9);
        let expect = 6.0 * gap + 1.0 * (gap - 1.0);
        assert!(
            (rep.stranded_instance_s - expect).abs() < 1e-9,
            "stranded {} expect {expect}",
            rep.stranded_instance_s
        );
    }
}
