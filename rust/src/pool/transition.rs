//! The drain → flip → warm role-transition state machine (DESIGN.md §3.6).
//!
//! A repartition never teleports an instance between pools. The tail
//! instance of the shrinking pool goes through three phases:
//!
//! 1. **Drain** — the instance stops admitting new work (routing, gating
//!    admission, rescue/restore destinations, and migration pulls all skip
//!    it); resident offline KV streams off through the transport engine
//!    (rescue / offload — the §3.4.1 recoverable-eviction machinery),
//!    in-flight *offline* inbound reservations are cancelled, and online
//!    work — residents and in-flight dispatches — finishes decoding in
//!    place so no online SLO is violated mid-transition.
//! 2. **Flip** — the instant the instance is empty it moves to the tail of
//!    the other pool (`ClusterState::flip_*`; tail-only movement keeps all
//!    other per-pool indices and `KvHome` entries valid).
//! 3. **Warm** — the flipped instance runs one `StepKind::Warm` step of
//!    [`WARMUP_S`] seconds (role-specific runtime re-initialization) before
//!    serving its new pool; the step occupies the instance, so ordinary
//!    idleness checks keep work away without special cases.
//!
//! At most one transition is in flight at a time; the pool manager simply
//! re-plans again if the load still warrants more movement.

use crate::instance::PoolRole;

/// Warm-up duration after a flip (s): role-specific runtime state —
/// scheduler caches, allocator pools, watermark re-init — modeled as one
/// fixed-cost step on both substrates.
pub const WARMUP_S: f64 = 1.0;

/// Phase of the in-flight role transition. (The flip itself is
/// instantaneous — it happens on the Drain→Warm edge.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransitionPhase {
    /// Emptying the instance in its old pool.
    Drain,
    /// Warm step running in the new pool.
    Warm,
}

/// One in-flight role transition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transition {
    /// Pool the instance is leaving.
    pub from: PoolRole,
    /// Index in the source pool while draining; index in the destination
    /// pool once flipped (both are the pool tail).
    pub inst: usize,
    pub phase: TransitionPhase,
    /// Drain start time (transition duration is measured from here to the
    /// end of the warm step).
    pub started: f64,
}

impl Transition {
    pub fn drain(from: PoolRole, inst: usize, now: f64) -> Self {
        Transition {
            from,
            inst,
            phase: TransitionPhase::Drain,
            started: now,
        }
    }

    /// The role the instance is moving to.
    pub fn to(&self) -> PoolRole {
        self.from.other()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transition_targets_other_pool() {
        let t = Transition::drain(PoolRole::Relaxed, 3, 12.5);
        assert_eq!(t.to(), PoolRole::Strict);
        assert_eq!(t.phase, TransitionPhase::Drain);
        assert_eq!(t.started, 12.5);
        let t = Transition::drain(PoolRole::Strict, 1, 0.0);
        assert_eq!(t.to(), PoolRole::Relaxed);
    }
}
