//! Roofline-guided capacity planning for the elastic pool manager
//! (DESIGN.md §3.6).
//!
//! The planner answers one question at every re-plan: *how many strict
//! instances does the estimated online load need to meet its TPOT SLO?*
//! It converts the burst-corrected arrival rate into an expected number of
//! concurrent online decodes via Little's law (`L = λ · W`, with the
//! per-request decode time `W` bounded by `output_len × TPOT`), splits
//! that residency evenly over a candidate strict pool, and asks the §3.3
//! roofline model whether the per-instance decode batch stays inside the
//! (headroom-reduced) TPOT budget and the instance's KV capacity. The
//! minimum feasible pool size wins; the remainder serves the relaxed pool.
//!
//! Monotonicity (property-tested): the roofline's decode latency is
//! monotone in batch size and KV tokens, so a larger estimated load can
//! never yield a *smaller* strict pool.

use crate::config::SloSpec;
use crate::perfmodel::{BatchStats, PerfModel};

use super::estimator::ClassLoad;

/// The load figures one plan is computed from.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlannerInput {
    /// Burst-corrected online arrival rate (req/s).
    pub online_rate: f64,
    /// Mean online prompt length (tokens).
    pub mean_prompt: f64,
    /// Mean online output length (tokens).
    pub mean_output: f64,
    /// EWMA fraction of admitted prompt tokens served from the prefix
    /// cache (DESIGN.md §3.7). Shared blocks are resident **once** per
    /// instance, not per request, so the planner's per-instance KV
    /// *capacity* check deflates the footprint by this fraction — without
    /// it, repartitioning would size the strict pool for KV the cache
    /// already deduplicates. Latency stays undeflated: attention reads
    /// every token per request regardless of block sharing. 0 = no cache.
    pub shared_kv_fraction: f64,
    /// Prefill-chunk reserve of the composed iteration model (DESIGN.md
    /// §3.8): the sizing no longer assumes pure-decode iterations — each
    /// candidate batch is priced as a *composed* iteration carrying this
    /// many chunk tokens (`PerfModel::mixed_iter_cost`). 0 = exclusive
    /// steps (the pre-§3.8 behaviour, byte-identical sizing).
    pub chunk_prefill_tokens: usize,
}

impl PlannerInput {
    pub fn from_load(l: &ClassLoad) -> Self {
        PlannerInput {
            online_rate: l.rate,
            mean_prompt: l.mean_prompt,
            mean_output: l.mean_output,
            shared_kv_fraction: 0.0,
            chunk_prefill_tokens: 0,
        }
    }

    /// Expected concurrent online decodes (Little's law at the TPOT bound:
    /// a request meeting its SLO resides at most `output × tpot` seconds).
    pub fn concurrent_decodes(&self, tpot: f64) -> f64 {
        (self.online_rate * self.mean_output * tpot).max(0.0)
    }

    /// Mean resident KV per online decode (prompt + half the output, the
    /// time-average of linear KV growth).
    pub fn mean_kv(&self) -> f64 {
        (self.mean_prompt + 0.5 * self.mean_output).max(1.0)
    }
}

/// Is a strict pool of `n` instances sufficient for `concurrent` decodes
/// of `mean_kv` tokens each within `budget` seconds per token? `share` is
/// the prefix-cache dedup fraction: it shrinks the resident footprint the
/// capacity check sees, never the latency (attention reads all tokens).
fn pool_feasible(
    pm: &PerfModel,
    n: usize,
    concurrent: f64,
    mean_kv: f64,
    share: f64,
    chunk: usize,
    budget: f64,
) -> bool {
    let batch = (concurrent / n as f64).ceil().max(1.0) as usize;
    let kv_tokens = (batch as f64 * mean_kv).ceil() as usize;
    let resident = unique_kv(kv_tokens, share);
    resident <= pm.max_kv_tokens()
        && pm
            .mixed_iter_cost(BatchStats::new(batch, kv_tokens), chunk)
            .latency_s
            <= budget
}

/// Deduplicated resident footprint of `kv_tokens` at cache share `share`.
fn unique_kv(kv_tokens: usize, share: f64) -> usize {
    let share = share.clamp(0.0, 0.95);
    ((kv_tokens as f64) * (1.0 - share)).ceil() as usize
}

/// Minimum strict-pool size (out of `total` instances) meeting the TPOT
/// SLO at the estimated load, with `headroom` of the budget held back.
/// Always leaves at least one instance per pool: the result is in
/// `1..=total-1` (with `total` clamped to ≥ 2).
pub fn min_strict_pool(
    pm: &PerfModel,
    slo: &SloSpec,
    load: &PlannerInput,
    total: usize,
    headroom: f64,
) -> usize {
    let total = total.max(2);
    let budget = slo.tpot * (1.0 - headroom.clamp(0.0, 0.9));
    let concurrent = load.concurrent_decodes(slo.tpot);
    if concurrent <= 0.0 {
        return 1;
    }
    let mean_kv = load.mean_kv();
    for n in 1..total {
        if pool_feasible(
            pm,
            n,
            concurrent,
            mean_kv,
            load.shared_kv_fraction,
            load.chunk_prefill_tokens,
            budget,
        ) {
            return n;
        }
    }
    // Even `total - 1` misses the SLO: give online everything we can
    // while keeping one prefill instance.
    total - 1
}

/// Largest per-instance decode batch of `mean_kv`-token requests that
/// stays within `budget` seconds — the strict pool's per-instance
/// capacity figure the `Reactive` trigger compares pressure against.
/// Returns 0 when even a single request misses the budget.
pub fn max_slo_batch(pm: &PerfModel, mean_kv: f64, budget: f64) -> usize {
    max_slo_batch_shared(pm, mean_kv, budget, 0.0)
}

/// [`max_slo_batch`] with prefix-cache dedup: the KV *capacity* bound sees
/// the deduplicated footprint, the latency bound the full token count.
pub fn max_slo_batch_shared(
    pm: &PerfModel,
    mean_kv: f64,
    budget: f64,
    share: f64,
) -> usize {
    max_slo_batch_chunked(pm, mean_kv, budget, share, 0)
}

/// [`max_slo_batch_shared`] under the composed iteration model (DESIGN.md
/// §3.8): each candidate batch is priced as a composed iteration carrying
/// `chunk` prefill tokens, so the capacity figure accounts for the chunk
/// reserve instead of assuming pure-decode iterations. `chunk = 0`
/// degenerates exactly to the pure-decode figure.
pub fn max_slo_batch_chunked(
    pm: &PerfModel,
    mean_kv: f64,
    budget: f64,
    share: f64,
    chunk: usize,
) -> usize {
    let mean_kv = mean_kv.max(1.0);
    let fits = |b: usize| -> bool {
        let kv = (b as f64 * mean_kv).ceil() as usize;
        unique_kv(kv, share) <= pm.max_kv_tokens()
            && pm.mixed_iter_cost(BatchStats::new(b, kv), chunk).latency_s
                <= budget
    };
    if !fits(1) {
        return 0;
    }
    // Exponential probe, then binary search on the monotone predicate.
    let mut lo = 1usize;
    let mut hi = 2usize;
    while hi < (1 << 22) && fits(hi) {
        lo = hi;
        hi *= 2;
    }
    while lo + 1 < hi {
        let mid = lo + (hi - lo) / 2;
        if fits(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Decode pressure given a precomputed per-instance capacity — the one
/// definition both [`strict_pressure`] and the `Reactive` trigger share
/// (the trigger hoists `max_slo_batch` out of its two threshold checks).
pub fn pressure_with_capacity(
    concurrent: f64,
    per_inst: usize,
    n: usize,
) -> f64 {
    if concurrent <= 0.0 {
        0.0
    } else if per_inst == 0 {
        f64::INFINITY
    } else {
        concurrent / (n.max(1) * per_inst) as f64
    }
}

/// Estimated decode pressure on a strict pool of `n` instances: expected
/// concurrent decodes over pool capacity. > 1 means the SLO is predicted
/// to fail; the `Reactive` policy's thresholds bracket it.
pub fn strict_pressure(
    pm: &PerfModel,
    slo: &SloSpec,
    load: &PlannerInput,
    n: usize,
) -> f64 {
    pressure_with_capacity(
        load.concurrent_decodes(slo.tpot),
        max_slo_batch_chunked(
            pm,
            load.mean_kv(),
            slo.tpot,
            load.shared_kv_fraction,
            load.chunk_prefill_tokens,
        ),
        n,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;

    fn setup() -> (PerfModel, SloSpec) {
        let cfg = ServingConfig::preset_7b();
        (PerfModel::new(cfg.model, cfg.hardware), cfg.slo)
    }

    fn load(rate: f64) -> PlannerInput {
        PlannerInput {
            online_rate: rate,
            mean_prompt: 1500.0,
            mean_output: 100.0,
            shared_kv_fraction: 0.0,
            chunk_prefill_tokens: 0,
        }
    }

    #[test]
    fn idle_load_needs_one_strict_instance() {
        let (pm, slo) = setup();
        assert_eq!(min_strict_pool(&pm, &slo, &load(0.0), 8, 0.15), 1);
    }

    #[test]
    fn heavier_load_grows_the_plan() {
        let (pm, slo) = setup();
        let small = min_strict_pool(&pm, &slo, &load(0.5), 8, 0.15);
        let big = min_strict_pool(&pm, &slo, &load(500.0), 8, 0.15);
        assert!(big >= small);
        assert!(big <= 7, "must leave a relaxed instance, got {big}");
        assert!(small >= 1);
    }

    #[test]
    fn monotone_in_rate() {
        let (pm, slo) = setup();
        let mut last = 0usize;
        for rate in [0.0, 0.2, 1.0, 3.0, 10.0, 40.0, 200.0, 1000.0] {
            let n = min_strict_pool(&pm, &slo, &load(rate), 6, 0.2);
            assert!(n >= last, "rate {rate}: {n} < {last}");
            last = n;
        }
    }

    #[test]
    fn headroom_never_shrinks_the_plan() {
        let (pm, slo) = setup();
        for rate in [1.0, 10.0, 100.0] {
            let loose = min_strict_pool(&pm, &slo, &load(rate), 8, 0.0);
            let tight = min_strict_pool(&pm, &slo, &load(rate), 8, 0.5);
            assert!(tight >= loose, "rate {rate}: {tight} < {loose}");
        }
    }

    #[test]
    fn max_slo_batch_is_positive_and_bounded() {
        let (pm, slo) = setup();
        let b = max_slo_batch(&pm, 1550.0, slo.tpot);
        assert!(b >= 1, "7B on a 910c must fit one decode in the SLO");
        // And the next batch over the answer really misses the budget
        // or the KV capacity.
        let kv = ((b + 1) as f64 * 1550.0).ceil() as usize;
        let over = kv > pm.max_kv_tokens()
            || pm.decode_latency(BatchStats::new(b + 1, kv)) > slo.tpot;
        assert!(over, "max_slo_batch {b} is not maximal");
        // Impossible budget -> zero.
        assert_eq!(max_slo_batch(&pm, 1550.0, 1e-9), 0);
    }

    #[test]
    fn cache_share_never_grows_the_plan() {
        // The deduplicated footprint relaxes only the KV-capacity bound:
        // a shared-prefix workload can need fewer strict instances at the
        // same load, never more (memory-bound regime), and the latency
        // bound keeps the plan honest.
        let (pm, slo) = setup();
        let mut squeezed = ServingConfig::preset_7b();
        squeezed.hardware.mem_capacity = 18e9; // KV capacity binds
        let pm_sq =
            PerfModel::new(squeezed.model.clone(), squeezed.hardware.clone());
        for rate in [0.5, 2.0, 8.0, 32.0] {
            let mut shared = load(rate);
            shared.shared_kv_fraction = 0.7;
            for (p, label) in [(&pm, "roomy"), (&pm_sq, "squeezed")] {
                let base = min_strict_pool(p, &slo, &load(rate), 8, 0.15);
                let with = min_strict_pool(p, &slo, &shared, 8, 0.15);
                assert!(
                    with <= base,
                    "{label} rate {rate}: share grew plan {base} -> {with}"
                );
            }
        }
        // And the per-instance capacity figure grows (or holds) with share.
        let b0 = max_slo_batch_shared(&pm_sq, 1550.0, slo.tpot, 0.0);
        let b7 = max_slo_batch_shared(&pm_sq, 1550.0, slo.tpot, 0.7);
        assert!(b7 >= b0, "share shrank capacity {b0} -> {b7}");
    }

    #[test]
    fn chunk_reserve_never_shrinks_the_plan() {
        // Composed-iteration sizing: reserving chunk room in the latency
        // budget can only demand an equal-or-larger strict pool, and the
        // per-instance capacity figure can only shrink (or hold).
        let (pm, slo) = setup();
        for rate in [0.5, 2.0, 8.0, 64.0] {
            let mut chunked = load(rate);
            chunked.chunk_prefill_tokens = 512;
            let base = min_strict_pool(&pm, &slo, &load(rate), 8, 0.15);
            let with = min_strict_pool(&pm, &slo, &chunked, 8, 0.15);
            assert!(
                with >= base,
                "rate {rate}: chunk reserve shrank plan {base} -> {with}"
            );
        }
        let b0 = max_slo_batch_chunked(&pm, 1550.0, slo.tpot, 0.0, 0);
        let b512 = max_slo_batch_chunked(&pm, 1550.0, slo.tpot, 0.0, 512);
        assert!(b512 <= b0, "chunk reserve grew capacity {b0} -> {b512}");
        // chunk = 0 degenerates to the pure-decode figure.
        assert_eq!(b0, max_slo_batch_shared(&pm, 1550.0, slo.tpot, 0.0));
    }

    #[test]
    fn pressure_scales_with_load_and_pool() {
        let (pm, slo) = setup();
        let p1 = strict_pressure(&pm, &slo, &load(2.0), 1);
        let p2 = strict_pressure(&pm, &slo, &load(4.0), 1);
        let p1_wide = strict_pressure(&pm, &slo, &load(2.0), 2);
        assert!(p2 > p1);
        assert!((p1_wide - p1 / 2.0).abs() < 1e-12);
        assert_eq!(strict_pressure(&pm, &slo, &load(0.0), 1), 0.0);
        // The shared low-level form handles the edge cases directly.
        assert_eq!(pressure_with_capacity(0.0, 10, 1), 0.0);
        assert_eq!(pressure_with_capacity(5.0, 0, 1), f64::INFINITY);
        assert_eq!(pressure_with_capacity(10.0, 5, 0), 2.0);
    }
}
