//! Load estimation for the elastic pool manager (DESIGN.md §3.6).
//!
//! Tracks per-class arrival rates and request-shape means from the action
//! stream's arrival events. Two exponentially weighted moving averages run
//! per class — a slow one (the tide tracker) and a fast one (the burst
//! tracker); the *burst-corrected* rate the planner consumes is the larger
//! of the two, so a minute-scale burst immediately inflates the plan while
//! the slow EWMA keeps the diurnal trend.
//!
//! The estimator is pure arithmetic over `(now, class, prompt, output)`
//! observations: it is part of [`crate::scheduler::SchedulerCore`]'s
//! substrate-independent state, so both executors reach identical
//! estimates and therefore identical repartition plans (differential-
//! tested).

use crate::request::Class;

/// One class's estimated load at a read instant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassLoad {
    /// Burst-corrected arrival rate (req/s): max(slow, fast EWMA), capped
    /// by what the silence since the last arrival can support.
    pub rate: f64,
    /// Slow-EWMA (tide-scale) arrival rate (req/s).
    pub steady_rate: f64,
    /// EWMA mean prompt length (tokens).
    pub mean_prompt: f64,
    /// EWMA mean output length (tokens).
    pub mean_output: f64,
}

impl ClassLoad {
    pub fn zero() -> Self {
        ClassLoad {
            rate: 0.0,
            steady_rate: 0.0,
            mean_prompt: 0.0,
            mean_output: 0.0,
        }
    }
}

#[derive(Debug, Clone, Default)]
struct ClassEst {
    count: u64,
    last_arrival: f64,
    rate_slow: f64,
    rate_fast: f64,
    mean_prompt: f64,
    mean_output: f64,
}

/// EWMA smoothing weight for request-shape means (prompt/output lengths).
const LEN_ALPHA: f64 = 0.05;

impl ClassEst {
    fn observe(
        &mut self,
        now: f64,
        tau_slow: f64,
        tau_fast: f64,
        prompt: usize,
        output: usize,
    ) {
        if self.count == 0 {
            // First arrival carries shape but no inter-arrival information.
            self.last_arrival = now;
            self.mean_prompt = prompt as f64;
            self.mean_output = output as f64;
            self.count = 1;
            return;
        }
        let dt = (now - self.last_arrival).max(1e-6);
        self.last_arrival = now;
        let inst_rate = 1.0 / dt;
        // Irregular-interval EWMA: weight by how much of the time constant
        // the gap consumed.
        let a_slow = 1.0 - (-dt / tau_slow).exp();
        let a_fast = 1.0 - (-dt / tau_fast).exp();
        self.rate_slow += a_slow * (inst_rate - self.rate_slow);
        self.rate_fast += a_fast * (inst_rate - self.rate_fast);
        self.mean_prompt += LEN_ALPHA * (prompt as f64 - self.mean_prompt);
        self.mean_output += LEN_ALPHA * (output as f64 - self.mean_output);
        self.count += 1;
    }

    fn load(&self, now: f64) -> ClassLoad {
        if self.count < 2 {
            return ClassLoad {
                rate: 0.0,
                steady_rate: 0.0,
                mean_prompt: self.mean_prompt,
                mean_output: self.mean_output,
            };
        }
        // Silence correction: `gap` seconds without an arrival bound the
        // plausible current rate at ~3 expected events over the gap, so a
        // stale-high estimate decays on the falling edge of a tide even
        // though EWMAs only update at arrivals.
        let gap = (now - self.last_arrival).max(0.0);
        let cap = if gap > 0.0 { 3.0 / gap } else { f64::INFINITY };
        ClassLoad {
            rate: self.rate_fast.max(self.rate_slow).min(cap),
            steady_rate: self.rate_slow.min(cap),
            mean_prompt: self.mean_prompt,
            mean_output: self.mean_output,
        }
    }
}

/// EWMA + burst-corrected arrival/demand tracker for both request classes.
#[derive(Debug, Clone)]
pub struct LoadEstimator {
    tau_slow: f64,
    tau_fast: f64,
    online: ClassEst,
    offline: ClassEst,
}

impl LoadEstimator {
    /// `tau_slow`/`tau_fast`: time constants (s) of the tide and burst
    /// EWMAs.
    pub fn new(tau_slow: f64, tau_fast: f64) -> Self {
        LoadEstimator {
            tau_slow: tau_slow.max(1e-3),
            tau_fast: tau_fast.max(1e-3),
            online: ClassEst::default(),
            offline: ClassEst::default(),
        }
    }

    /// Tide-scale 120 s / burst-scale 15 s defaults.
    pub fn default_taus() -> Self {
        LoadEstimator::new(120.0, 15.0)
    }

    /// Feed one arrival observation.
    pub fn observe_arrival(
        &mut self,
        now: f64,
        class: Class,
        prompt: usize,
        output: usize,
    ) {
        let est = match class {
            Class::Online => &mut self.online,
            Class::Offline => &mut self.offline,
        };
        est.observe(now, self.tau_slow, self.tau_fast, prompt, output);
    }

    /// Estimated online load at `now`.
    pub fn online(&self, now: f64) -> ClassLoad {
        self.online.load(now)
    }

    /// Estimated offline load at `now`.
    pub fn offline(&self, now: f64) -> ClassLoad {
        self.offline.load(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed_uniform(e: &mut LoadEstimator, rate: f64, t0: f64, t1: f64) {
        let dt = 1.0 / rate;
        let mut t = t0;
        while t < t1 {
            e.observe_arrival(t, Class::Online, 1000, 100);
            t += dt;
        }
    }

    #[test]
    fn converges_to_uniform_rate() {
        let mut e = LoadEstimator::new(30.0, 5.0);
        feed_uniform(&mut e, 4.0, 0.0, 300.0);
        let l = e.online(300.0);
        assert!((l.rate - 4.0).abs() / 4.0 < 0.05, "rate {}", l.rate);
        assert!((l.steady_rate - 4.0).abs() / 4.0 < 0.05);
        assert!((l.mean_prompt - 1000.0).abs() < 1.0);
        assert!((l.mean_output - 100.0).abs() < 1.0);
    }

    #[test]
    fn burst_correction_reacts_faster_than_tide() {
        let mut e = LoadEstimator::new(120.0, 5.0);
        feed_uniform(&mut e, 2.0, 0.0, 300.0);
        let before = e.online(300.0);
        // 20 s burst at 5x the base rate.
        feed_uniform(&mut e, 10.0, 300.0, 320.0);
        let during = e.online(320.0);
        assert!(
            during.rate > 2.0 * before.rate,
            "burst-corrected rate must jump: {} -> {}",
            before.rate,
            during.rate
        );
        // The slow tide estimate lags far behind the burst tracker.
        assert!(
            during.steady_rate < 0.5 * during.rate,
            "tide estimate {} vs burst {}",
            during.steady_rate,
            during.rate
        );
    }

    #[test]
    fn silence_decays_stale_estimates() {
        let mut e = LoadEstimator::new(30.0, 5.0);
        feed_uniform(&mut e, 10.0, 0.0, 120.0);
        assert!(e.online(120.0).rate > 8.0);
        // One minute of silence: a 10/s estimate is no longer credible.
        let l = e.online(180.0);
        assert!(l.rate <= 3.0 / 60.0 + 1e-9, "stale rate {}", l.rate);
    }

    #[test]
    fn classes_tracked_independently() {
        let mut e = LoadEstimator::default_taus();
        e.observe_arrival(0.0, Class::Offline, 2000, 500);
        e.observe_arrival(1.0, Class::Offline, 2000, 500);
        let online = e.online(1.0);
        assert_eq!(online.rate, 0.0);
        assert!(e.offline(1.0).rate > 0.0);
        assert!((e.offline(1.0).mean_output - 500.0).abs() < 1.0);
    }

    #[test]
    fn single_arrival_reports_zero_rate() {
        let mut e = LoadEstimator::default_taus();
        e.observe_arrival(5.0, Class::Online, 100, 10);
        let l = e.online(5.0);
        assert_eq!(l.rate, 0.0);
        assert_eq!(l.mean_prompt, 100.0);
    }
}
