//! [`ClusterState`]: everything the §3.4 decision loop reasons about —
//! the two latency-constraint pools, the shared offline backlog, per-request
//! KV residency, and the load-balancing router. Pure state; all transitions
//! happen in [`super::SchedulerCore`], all time in an [`super::Executor`].

use std::collections::VecDeque;

use crate::coordinator::Router;
use crate::instance::{RelaxedInstance, StrictInstance};
use crate::perfmodel::BatchStats;
use crate::request::{Request, RequestId};

/// Where a not-yet-decoding request's KV currently lives.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KvHome {
    None,
    Relaxed(usize),
    Strict(usize),
    /// Host staging buffer (recoverable fast preemption parked the KV off
    /// the devices; a `Restore` transfer brings it back).
    Staged,
}

/// Scheduling state for one cluster: instances, backlog, KV homes, router.
#[derive(Debug)]
pub struct ClusterState {
    /// All requests of the workload, indexed by `RequestId`.
    pub requests: Vec<Request>,
    /// Per-request KV location index (O(1) residency checks on the decode
    /// hot path).
    pub kv_home: Vec<KvHome>,
    pub relaxed: Vec<RelaxedInstance>,
    pub strict: Vec<StrictInstance>,
    /// Offline requests waiting for (re-)prefill, shared across the pool.
    pub offline_backlog: VecDeque<RequestId>,
    /// Offline requests whose KV sits in the host staging buffer
    /// (recoverable fast preemption), waiting for relaxed-pool space to
    /// stream back in.
    pub staged_offline: VecDeque<RequestId>,
    pub router: Router,
    /// Per-strict-instance (batch stats, all-included) of the running step,
    /// consumed by the Algorithm 1 decision at the step boundary.
    pub strict_step_meta: Vec<Option<(BatchStats, bool)>>,
    /// Per-request time of the recoverable eviction currently being
    /// recovered from (NaN = none); cleared when decode resumes.
    pub evict_started: Vec<f64>,
    /// Preemption-to-restart latencies of recovered evictions (s).
    pub restart_latencies: Vec<f64>,
    // ---- counters ----
    /// Online arrivals truncating a running offline prefill (§3.4.1).
    pub preemptions: u64,
    /// Offline KV drops (strict + relaxed) forcing recompute.
    pub evictions: u64,
    /// Algorithm 1 pulls (offline decode relaxed -> strict).
    pub migrations: u64,
    /// Strict evictions recovered by streaming KV into the relaxed pool.
    pub rescues: u64,
    /// Evictions recovered by streaming KV to host staging.
    pub offloads: u64,
    /// Staged KV streams restored to a relaxed instance.
    pub restores: u64,
}

impl ClusterState {
    /// Build the cluster for `requests` with `n_relaxed`/`n_strict`
    /// instances of `kv_capacity_tokens` each. Requests are re-sorted by id
    /// so `requests[rid]` indexing holds for traces whose arrival order
    /// differs from id order.
    pub fn new(
        mut requests: Vec<Request>,
        n_relaxed: usize,
        n_strict: usize,
        kv_capacity_tokens: usize,
        block_tokens: usize,
    ) -> Self {
        requests.sort_by_key(|r| r.id);
        debug_assert!(
            requests.iter().enumerate().all(|(i, r)| r.id == i as u64),
            "request ids must be dense 0..n"
        );
        let n_relaxed = n_relaxed.max(1);
        let n_strict = n_strict.max(1);
        let relaxed = (0..n_relaxed)
            .map(|i| RelaxedInstance::new(i, kv_capacity_tokens, block_tokens))
            .collect();
        let strict = (0..n_strict)
            .map(|i| StrictInstance::new(i, kv_capacity_tokens, block_tokens))
            .collect();
        ClusterState {
            kv_home: vec![KvHome::None; requests.len()],
            evict_started: vec![f64::NAN; requests.len()],
            requests,
            relaxed,
            strict,
            offline_backlog: VecDeque::new(),
            staged_offline: VecDeque::new(),
            router: Router::new(n_relaxed, n_strict),
            strict_step_meta: vec![None; n_strict],
            restart_latencies: Vec::new(),
            preemptions: 0,
            evictions: 0,
            migrations: 0,
            rescues: 0,
            offloads: 0,
            restores: 0,
        }
    }

    /// No queued, running, or in-flight work anywhere in the cluster.
    /// (The backlog may legitimately stay non-empty when gating keeps
    /// rejecting; executors treat "drained" as a stop condition only once
    /// no more events can fire.)
    pub fn drained(&self) -> bool {
        self.offline_backlog.is_empty()
            && self.staged_offline.is_empty()
            && self.relaxed.iter().all(|r| {
                r.step.is_none()
                    && r.online_queue.is_empty()
                    && r.offline_decoding.is_empty()
                    && r.inbound.is_empty()
            })
            && self.strict.iter().all(|s| {
                s.step.is_none()
                    && s.online.is_empty()
                    && s.offline.is_empty()
                    && s.inbound.is_empty()
                    && s.waiting_for_space.is_empty()
            })
    }

    /// Aggregate busy seconds over the strict pool.
    pub fn strict_busy_s(&self) -> f64 {
        self.strict.iter().map(|s| s.busy_s).sum()
    }

    /// Aggregate busy seconds over the relaxed pool.
    pub fn relaxed_busy_s(&self) -> f64 {
        self.relaxed.iter().map(|r| r.busy_s).sum()
    }

    /// Total strict decode iterations executed so far.
    pub fn strict_steps(&self) -> u64 {
        self.strict.iter().map(|s| s.steps).sum()
    }

    /// Offline tokens decoded on strict instances (mix-in volume).
    pub fn strict_offline_tokens(&self) -> u64 {
        self.strict.iter().map(|s| s.offline_decode_tokens).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::Class;

    fn reqs(n: usize) -> Vec<Request> {
        (0..n)
            .map(|i| Request::new(i as u64, Class::Online, i as f64, 10, 2))
            .collect()
    }

    #[test]
    fn new_clamps_instance_counts() {
        let c = ClusterState::new(reqs(3), 0, 0, 1000, 16);
        assert_eq!(c.relaxed.len(), 1);
        assert_eq!(c.strict.len(), 1);
        assert_eq!(c.kv_home.len(), 3);
        assert!(c.drained());
    }

    #[test]
    fn reorders_requests_by_id() {
        let mut rs = reqs(4);
        rs.reverse();
        let c = ClusterState::new(rs, 1, 1, 1000, 16);
        for (i, r) in c.requests.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn drained_tracks_backlog_and_residents() {
        let mut c = ClusterState::new(reqs(2), 1, 1, 1000, 16);
        assert!(c.drained());
        c.offline_backlog.push_back(0);
        assert!(!c.drained());
        c.offline_backlog.clear();
        c.strict[0].online.push(1);
        assert!(!c.drained());
    }
}
