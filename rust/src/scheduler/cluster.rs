//! [`ClusterState`]: everything the §3.4 decision loop reasons about —
//! the two latency-constraint pools, the shared offline backlog, per-request
//! KV residency, and the load-balancing router. Pure state; all transitions
//! happen in [`super::SchedulerCore`], all time in an [`super::Executor`].
//!
//! Pool membership is runtime state (DESIGN.md §3.6): `relaxed` and
//! `strict` hold the *same* unified [`Instance`] type, and the elastic pool
//! manager moves drained instances between the two vectors at the tail
//! ([`ClusterState::flip_relaxed_to_strict`] /
//! [`ClusterState::flip_strict_to_relaxed`]), so per-pool indices of every
//! other instance — and therefore every [`KvHome`] entry — stay stable
//! across repartitions.

use std::collections::VecDeque;

use crate::coordinator::Router;
use crate::instance::{Instance, PoolRole};
use crate::perfmodel::BatchStats;
use crate::request::{Request, RequestId};
use crate::util::stats::LatencySummary;

/// Where a not-yet-decoding request's KV currently lives.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KvHome {
    None,
    Relaxed(usize),
    Strict(usize),
    /// Host staging buffer (recoverable fast preemption parked the KV off
    /// the devices; a `Restore` transfer brings it back).
    Staged,
}

/// Scheduling state for one cluster: instances, backlog, KV homes, router.
#[derive(Debug)]
pub struct ClusterState {
    /// All requests of the workload, indexed by `RequestId`.
    pub requests: Vec<Request>,
    /// Per-request KV location index (O(1) residency checks on the decode
    /// hot path).
    pub kv_home: Vec<KvHome>,
    pub relaxed: Vec<Instance>,
    pub strict: Vec<Instance>,
    /// Offline requests waiting for (re-)prefill, shared across the pool.
    pub offline_backlog: VecDeque<RequestId>,
    /// Offline requests whose KV sits in the host staging buffer
    /// (recoverable fast preemption), waiting for relaxed-pool space to
    /// stream back in.
    pub staged_offline: VecDeque<RequestId>,
    pub router: Router,
    /// Per-strict-instance (batch stats, all-included) of the running step,
    /// consumed by the Algorithm 1 decision at the step boundary.
    pub strict_step_meta: Vec<Option<(BatchStats, bool)>>,
    /// Cluster-global step sequence counter. Seq ids are unique across
    /// *all* instances and all time — so a stale step-end event addressed
    /// to a pool index that an elastic flip has since vacated (or refilled
    /// with a different instance) can never coincidentally match a live
    /// step's seq.
    pub next_seq: u64,
    /// Per-request time of the recoverable eviction currently being
    /// recovered from (NaN = none); cleared when decode resumes.
    pub evict_started: Vec<f64>,
    /// Preemption-to-restart latencies of recovered evictions (s),
    /// accumulated as a streaming histogram (O(buckets) memory).
    pub restart_latency: LatencySummary,
    // ---- role-scoped accounting across flips ----
    /// Busy seconds earned by instances *while serving a role they have
    /// since flipped away from* (an instance's live counters are retired
    /// here and zeroed at each flip, so per-role sums never mix roles).
    pub retired_relaxed_busy_s: f64,
    pub retired_strict_busy_s: f64,
    pub retired_strict_steps: u64,
    pub retired_strict_offline_tokens: u64,
    /// Time-integrated per-role instance counts (instance-seconds), accrued
    /// at every role change via [`ClusterState::accrue_role_seconds`] —
    /// the honest utilization denominator under elastic repartitioning.
    pub relaxed_inst_s: f64,
    pub strict_inst_s: f64,
    last_role_change_t: f64,
    // ---- counters ----
    /// Online arrivals truncating a running offline prefill (§3.4.1).
    pub preemptions: u64,
    /// Offline KV drops (strict + relaxed) forcing recompute.
    pub evictions: u64,
    /// Algorithm 1 pulls (offline decode relaxed -> strict).
    pub migrations: u64,
    /// Strict evictions recovered by streaming KV into the relaxed pool.
    pub rescues: u64,
    /// Evictions recovered by streaming KV to host staging.
    pub offloads: u64,
    /// Staged KV streams restored to a relaxed instance.
    pub restores: u64,
    // ---- fleet fault-model accounting (DESIGN.md §3.9) ----
    /// Instance crashes delivered to this cluster.
    pub crashes: u64,
    /// Instance recoveries delivered to this cluster.
    pub recoveries: u64,
    /// Requests whose KV a crash destroyed (forced recompute).
    pub crash_evictions: u64,
    /// KV tokens destroyed by crashes — the discard-and-recompute cost.
    pub crash_recompute_tokens: u64,
    /// KV tokens evacuated ahead of a crash (advance notice) through the
    /// recoverable-eviction transport paths — recompute avoided.
    pub crash_evac_tokens: u64,
    // ---- prefix-sharing cache accounting (DESIGN.md §3.7) ----
    /// Cache resolutions at prefill admission (requests with a declared
    /// shared prefix only).
    pub prefix_lookups: u64,
    /// Resolutions that matched at least one cached block.
    pub prefix_hits: u64,
    /// Prompt tokens served from cache (prefill recompute skipped), by
    /// scheduled class.
    pub prefix_hit_tokens_online: u64,
    pub prefix_hit_tokens_offline: u64,
    /// Prompt tokens admitted to prefill (hit-rate denominator; all
    /// requests, shared prefix declared or not).
    pub prefix_prompt_tokens: u64,
    /// Reclaimable cache blocks evicted (LRU reclaim + drain purges).
    pub prefix_evicted_blocks: u64,
    /// KV tokens *not* moved by dispatch/migration/rescue/restore because
    /// the destination already held the prefix blocks.
    pub transfer_tokens_saved: u64,
    /// Time-integral of reclaimable cached blocks (block·s) — capacity
    /// held as cache while staying admittable.
    pub cached_block_s: f64,
    last_cache_t: f64,
    // ---- chunked-prefill iteration accounting (DESIGN.md §3.8) ----
    /// Composed iterations started (chunked mode only).
    pub chunk_steps: u64,
    /// Composed iterations carrying both decode work and prefill chunks
    /// (the genuinely mixed ones).
    pub chunk_mixed_steps: u64,
    /// Prefill chunk segments scheduled.
    pub chunk_segments: u64,
    /// Uncached prompt tokens prefilled through chunk segments.
    pub chunk_prefill_tokens: u64,
    /// Sum of per-iteration chunk budgets over iterations that scheduled
    /// at least one segment (utilization denominator).
    pub chunk_budget_offered: u64,
    /// Prefill/decode interference: Σ over mixed iterations of
    /// (composed latency − pure-decode latency) — the delay chunked
    /// prefill adds to co-resident decodes.
    pub chunk_interference_s: f64,
    /// Prefill tokens already computed when an online arrival halted
    /// offline chunk scheduling — work the exclusive-step preemption
    /// would have discarded, retained by the cursor.
    pub chunk_retained_tokens: u64,
    /// Prefill work discarded by exclusive-step preemption truncation
    /// (layer-level discard-and-recompute; structurally 0 when chunking
    /// is on).
    pub chunk_discarded_tokens: u64,
    /// Cursor/target mismatches detected at prefill completion (lost or
    /// double-counted chunks — property-tested to stay 0).
    pub chunk_accounting_errors: u64,
}

impl ClusterState {
    /// Build the cluster for `requests` with `n_relaxed`/`n_strict`
    /// instances of `kv_capacity_tokens` each. Requests are re-sorted by id
    /// so `requests[rid]` indexing holds for traces whose arrival order
    /// differs from id order.
    pub fn new(
        mut requests: Vec<Request>,
        n_relaxed: usize,
        n_strict: usize,
        kv_capacity_tokens: usize,
        block_tokens: usize,
    ) -> Self {
        requests.sort_by_key(|r| r.id);
        debug_assert!(
            requests.iter().enumerate().all(|(i, r)| r.id == i as u64),
            "request ids must be dense 0..n"
        );
        let n_relaxed = n_relaxed.max(1);
        let n_strict = n_strict.max(1);
        let relaxed = (0..n_relaxed)
            .map(|i| {
                Instance::new(i, PoolRole::Relaxed, kv_capacity_tokens, block_tokens)
            })
            .collect();
        let strict = (0..n_strict)
            .map(|i| {
                Instance::new(i, PoolRole::Strict, kv_capacity_tokens, block_tokens)
            })
            .collect();
        ClusterState {
            kv_home: vec![KvHome::None; requests.len()],
            evict_started: vec![f64::NAN; requests.len()],
            requests,
            relaxed,
            strict,
            offline_backlog: VecDeque::new(),
            staged_offline: VecDeque::new(),
            router: Router::new(n_relaxed, n_strict),
            strict_step_meta: vec![None; n_strict],
            next_seq: 0,
            retired_relaxed_busy_s: 0.0,
            retired_strict_busy_s: 0.0,
            retired_strict_steps: 0,
            retired_strict_offline_tokens: 0,
            relaxed_inst_s: 0.0,
            strict_inst_s: 0.0,
            last_role_change_t: 0.0,
            restart_latency: LatencySummary::new(),
            preemptions: 0,
            evictions: 0,
            migrations: 0,
            rescues: 0,
            offloads: 0,
            restores: 0,
            crashes: 0,
            recoveries: 0,
            crash_evictions: 0,
            crash_recompute_tokens: 0,
            crash_evac_tokens: 0,
            prefix_lookups: 0,
            prefix_hits: 0,
            prefix_hit_tokens_online: 0,
            prefix_hit_tokens_offline: 0,
            prefix_prompt_tokens: 0,
            prefix_evicted_blocks: 0,
            transfer_tokens_saved: 0,
            cached_block_s: 0.0,
            last_cache_t: 0.0,
            chunk_steps: 0,
            chunk_mixed_steps: 0,
            chunk_segments: 0,
            chunk_prefill_tokens: 0,
            chunk_budget_offered: 0,
            chunk_interference_s: 0.0,
            chunk_retained_tokens: 0,
            chunk_discarded_tokens: 0,
            chunk_accounting_errors: 0,
        }
    }

    /// Current reclaimable (cached, unpinned) blocks across the cluster.
    pub fn reclaimable_cache_blocks(&self) -> usize {
        self.relaxed
            .iter()
            .chain(&self.strict)
            .map(|i| i.kv.reclaimable_blocks())
            .sum()
    }

    /// Integrate reclaimable-cache block·s up to `now`. Called at the top
    /// of every core entry point, before any cache mutation.
    pub fn accrue_cache_seconds(&mut self, now: f64) {
        let dt = (now - self.last_cache_t).max(0.0);
        if dt > 0.0 {
            self.cached_block_s +=
                dt * self.reclaimable_cache_blocks() as f64;
        }
        self.last_cache_t = now;
    }

    /// Reclaimable-cache block·s over `[0, until]` (read-only projection).
    pub fn cache_block_seconds(&self, until: f64) -> f64 {
        let dt = (until - self.last_cache_t).max(0.0);
        self.cached_block_s + dt * self.reclaimable_cache_blocks() as f64
    }

    /// Cluster size — invariant across repartitions (property-tested).
    pub fn total_instances(&self) -> usize {
        self.relaxed.len() + self.strict.len()
    }

    /// Allocate a cluster-unique step sequence id.
    pub fn alloc_seq(&mut self) -> u64 {
        self.next_seq += 1;
        self.next_seq
    }

    /// Integrate per-role instance-seconds up to `now`. Called by the core
    /// immediately before every role flip (and by metrics readers via
    /// [`ClusterState::role_instance_seconds`]).
    pub fn accrue_role_seconds(&mut self, now: f64) {
        let dt = (now - self.last_role_change_t).max(0.0);
        self.relaxed_inst_s += dt * self.relaxed.len() as f64;
        self.strict_inst_s += dt * self.strict.len() as f64;
        self.last_role_change_t = now;
    }

    /// Per-role instance-seconds over `[0, until]` (read-only projection of
    /// the accrual). With no flips this is exactly `until × pool size`.
    pub fn role_instance_seconds(&self, until: f64) -> (f64, f64) {
        let dt = (until - self.last_role_change_t).max(0.0);
        (
            self.relaxed_inst_s + dt * self.relaxed.len() as f64,
            self.strict_inst_s + dt * self.strict.len() as f64,
        )
    }

    /// Move the drained tail relaxed instance into the strict pool;
    /// returns its new strict index. Tail-only movement keeps every other
    /// per-pool index (and `KvHome`) valid. The instance's relaxed-role
    /// busy time is retired into the cluster accumulator and its counters
    /// zeroed, so per-role sums never mix roles across flips.
    pub fn flip_relaxed_to_strict(&mut self) -> usize {
        assert!(self.relaxed.len() > 1, "cannot flip the last relaxed instance");
        let mut inst = self.relaxed.pop().expect("non-empty");
        assert!(inst.drained_for_flip(), "flip of a non-drained instance");
        self.retired_relaxed_busy_s += inst.busy_s;
        inst.busy_s = 0.0;
        // Strict-role counters were zeroed when it last left that role.
        debug_assert_eq!(inst.steps, 0);
        let new_idx = self.strict.len();
        inst.id = new_idx;
        inst.role = PoolRole::Strict;
        inst.draining = false;
        self.strict.push(inst);
        self.strict_step_meta.push(None);
        self.router.flip_relaxed_to_strict();
        new_idx
    }

    /// Move the drained tail strict instance into the relaxed pool;
    /// returns its new relaxed index (strict-role counters retire like
    /// [`ClusterState::flip_relaxed_to_strict`]'s).
    pub fn flip_strict_to_relaxed(&mut self) -> usize {
        assert!(self.strict.len() > 1, "cannot flip the last strict instance");
        let mut inst = self.strict.pop().expect("non-empty");
        assert!(inst.drained_for_flip(), "flip of a non-drained instance");
        self.retired_strict_busy_s += inst.busy_s;
        self.retired_strict_steps += inst.steps;
        self.retired_strict_offline_tokens += inst.offline_decode_tokens;
        inst.busy_s = 0.0;
        inst.steps = 0;
        inst.offline_decode_tokens = 0;
        self.strict_step_meta.pop();
        let new_idx = self.relaxed.len();
        inst.id = new_idx;
        inst.role = PoolRole::Relaxed;
        inst.draining = false;
        self.relaxed.push(inst);
        self.router.flip_strict_to_relaxed();
        new_idx
    }

    /// No queued, running, or in-flight work anywhere in the cluster.
    /// (The backlog may legitimately stay non-empty when gating keeps
    /// rejecting; executors treat "drained" as a stop condition only once
    /// no more events can fire.) Retained prefix-cache blocks are *not*
    /// work: only pinned KV counts.
    pub fn drained(&self) -> bool {
        self.offline_backlog.is_empty()
            && self.staged_offline.is_empty()
            && self
                .relaxed
                .iter()
                .chain(&self.strict)
                .all(|i| i.workload_empty() && i.kv.pinned_blocks() == 0)
    }

    /// Is `rid` tracked by any scheduling structure — a queue, a resident
    /// list, an in-flight transfer, the backlog, or host staging? The
    /// fleet's no-lost-request accounting check: every unfinished request
    /// must be held *somewhere*, crash or no crash.
    pub fn holds(&self, rid: RequestId) -> bool {
        let in_instance = |i: &Instance| {
            i.online_queue.contains(&rid)
                || i.prefilling.contains(&rid)
                || i.offline_decoding.contains(&rid)
                || i.online.contains(&rid)
                || i.offline.contains(&rid)
                || i.waiting_for_space.contains(&rid)
                || i.inbound.contains(&rid)
        };
        self.offline_backlog.contains(&rid)
            || self.staged_offline.contains(&rid)
            || self.relaxed.iter().chain(&self.strict).any(in_instance)
    }

    /// Aggregate busy seconds earned in the strict role (live + retired).
    pub fn strict_busy_s(&self) -> f64 {
        self.retired_strict_busy_s
            + self.strict.iter().map(|s| s.busy_s).sum::<f64>()
    }

    /// Aggregate busy seconds earned in the relaxed role (live + retired).
    pub fn relaxed_busy_s(&self) -> f64 {
        self.retired_relaxed_busy_s
            + self.relaxed.iter().map(|r| r.busy_s).sum::<f64>()
    }

    /// Total strict decode iterations executed so far.
    pub fn strict_steps(&self) -> u64 {
        self.retired_strict_steps
            + self.strict.iter().map(|s| s.steps).sum::<u64>()
    }

    /// Offline tokens decoded on strict instances (mix-in volume).
    pub fn strict_offline_tokens(&self) -> u64 {
        self.retired_strict_offline_tokens
            + self
                .strict
                .iter()
                .map(|s| s.offline_decode_tokens)
                .sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::Class;

    fn reqs(n: usize) -> Vec<Request> {
        (0..n)
            .map(|i| Request::new(i as u64, Class::Online, i as f64, 10, 2))
            .collect()
    }

    #[test]
    fn new_clamps_instance_counts() {
        let c = ClusterState::new(reqs(3), 0, 0, 1000, 16);
        assert_eq!(c.relaxed.len(), 1);
        assert_eq!(c.strict.len(), 1);
        assert_eq!(c.kv_home.len(), 3);
        assert!(c.drained());
    }

    #[test]
    fn reorders_requests_by_id() {
        let mut rs = reqs(4);
        rs.reverse();
        let c = ClusterState::new(rs, 1, 1, 1000, 16);
        for (i, r) in c.requests.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn drained_tracks_backlog_and_residents() {
        let mut c = ClusterState::new(reqs(2), 1, 1, 1000, 16);
        assert!(c.drained());
        c.offline_backlog.push_back(0);
        assert!(!c.drained());
        c.offline_backlog.clear();
        c.strict[0].online.push(1);
        assert!(!c.drained());
    }

    #[test]
    fn role_seconds_integrate_across_flips() {
        let mut c = ClusterState::new(reqs(2), 2, 1, 1000, 16);
        c.accrue_role_seconds(10.0); // 10 s at 2r/1s
        c.flip_relaxed_to_strict();
        let (r, s) = c.role_instance_seconds(30.0); // +20 s at 1r/2s
        assert!((r - (10.0 * 2.0 + 20.0)).abs() < 1e-9, "relaxed {r}");
        assert!((s - (10.0 + 20.0 * 2.0)).abs() < 1e-9, "strict {s}");
        // Static clusters reduce to duration × size.
        let c2 = ClusterState::new(reqs(2), 2, 1, 1000, 16);
        assert_eq!(c2.role_instance_seconds(50.0), (100.0, 50.0));
    }

    #[test]
    fn flips_retire_role_scoped_counters() {
        let mut c = ClusterState::new(reqs(2), 2, 1, 1000, 16);
        c.relaxed[1].busy_s = 7.0;
        c.flip_relaxed_to_strict();
        // Relaxed busy stays attributed to the relaxed role...
        assert_eq!(c.relaxed_busy_s(), 7.0);
        // ...and the flipped instance starts its strict life at zero.
        assert_eq!(c.strict_busy_s(), 0.0);
        c.strict[1].busy_s = 3.0;
        c.strict[1].steps = 5;
        c.strict[1].offline_decode_tokens = 11;
        c.flip_strict_to_relaxed();
        assert_eq!(c.strict_busy_s(), 3.0);
        assert_eq!(c.strict_steps(), 5);
        assert_eq!(c.strict_offline_tokens(), 11);
        assert_eq!(c.relaxed_busy_s(), 7.0);
    }

    #[test]
    fn flips_conserve_instances_and_update_roles() {
        let mut c = ClusterState::new(reqs(2), 2, 1, 1000, 16);
        assert_eq!(c.total_instances(), 3);
        let idx = c.flip_relaxed_to_strict();
        assert_eq!(idx, 1);
        assert_eq!(c.relaxed.len(), 1);
        assert_eq!(c.strict.len(), 2);
        assert_eq!(c.total_instances(), 3);
        assert_eq!(c.strict[1].role, PoolRole::Strict);
        assert_eq!(c.strict[1].id, 1);
        assert_eq!(c.strict_step_meta.len(), 2);
        assert_eq!(c.router.strict_count(), 2);
        // And back.
        let idx = c.flip_strict_to_relaxed();
        assert_eq!(idx, 1);
        assert_eq!(c.relaxed.len(), 2);
        assert_eq!(c.relaxed[1].role, PoolRole::Relaxed);
        assert_eq!(c.strict_step_meta.len(), 1);
        assert_eq!(c.total_instances(), 3);
    }

    #[test]
    #[should_panic]
    fn flip_of_busy_instance_panics() {
        let mut c = ClusterState::new(reqs(2), 2, 1, 1000, 16);
        c.relaxed[1].online_queue.push_back(0);
        c.flip_relaxed_to_strict();
    }
}
