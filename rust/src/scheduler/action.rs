//! Typed scheduling decisions — the protocol between [`super::SchedulerCore`]
//! and an [`super::Executor`].
//!
//! Every entry point on the core returns a `Vec<Action>` describing what the
//! substrate must do next. Actions split into two kinds:
//!
//! - **work orders** the executor must act on: [`Action::StartStep`] (run an
//!   iteration and call `on_step_end` when it finishes),
//!   [`Action::TransferChunk`] (move one KV chunk over a link and call
//!   `on_transfer_progress` when it lands), and [`Action::Preempt`]
//!   (reschedule a truncated offline-prefill step);
//! - **notifications** that carry no scheduling obligation but let the
//!   executor track per-request resources (real KV buffers, staging copies,
//!   logs, metrics): [`Action::TransferStart`], [`Action::TransferDone`],
//!   [`Action::TransferCancel`], [`Action::Evict`], [`Action::Migrate`],
//!   [`Action::Admit`], [`Action::Complete`], the prefix-cache
//!   hit/miss/evict stream ([`Action::PrefixResolve`],
//!   [`Action::PrefixEvict`] — DESIGN.md §3.7), and the elastic pool
//!   manager's plan timeline — [`Action::RepartitionPlan`] and
//!   [`Action::RoleChange`] (the timed warm-up after a flip rides on an
//!   ordinary [`Action::StartStep`] with [`StepKind::Warm`]).
//!
//! The stream of actions is the core's *observable behaviour*: two executors
//! driving the same core over the same trace must produce identical streams
//! — including the chunk-level transfer progress/completion ordering under
//! link contention (asserted by `tests/scheduler_differential.rs`). All
//! scheduling state (queues, KV accounting, routing, the transport engine)
//! lives in the core; executors only own the clock and the execution
//! substrate.

use crate::instance::{PoolRole, PrefillSegment, StepKind};
use crate::request::RequestId;
use crate::transport::{JobId, TransferKind};

/// Phase of an elastic role transition (DESIGN.md §3.6) announced by
/// [`Action::RoleChange`]: drain → flip → warm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RolePhase {
    /// The instance stopped admitting new work and is emptying.
    Drain,
    /// The drained instance moved to the tail of its new pool.
    Flip,
    /// The warm-up step finished; the instance now serves its new pool.
    Warm,
}

/// Which pool instance an action refers to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstanceRef {
    /// Latency-relaxed instance (prefill + offline decode).
    Relaxed(usize),
    /// Latency-strict instance (online decode + SLO-bounded mix-in).
    Strict(usize),
}

/// One scheduling decision emitted at a step boundary (§3.4).
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// Begin an iteration on `inst` with `participants`. The executor must
    /// invoke [`super::SchedulerCore::on_step_end`] with this `seq` when the
    /// step completes — after `predicted_latency` on a virtual clock, or
    /// after the measured execution on a real substrate.
    StartStep {
        inst: InstanceRef,
        kind: StepKind,
        /// Decode participants (plus, in exclusive-step mode, the prefill
        /// batch of a `Prefill*` step).
        participants: Vec<RequestId>,
        /// Chunked-prefill segments of a [`StepKind::Composed`] iteration
        /// (DESIGN.md §3.8): per-request uncached token slices drawn from
        /// the progress cursors. Empty for exclusive-step, decode, and
        /// warm steps. Part of the differential stream, so both executors
        /// must compose identically.
        prefill: Vec<PrefillSegment>,
        /// Roofline-predicted iteration latency (s). The virtual executor
        /// uses it as the actual duration; real executors measure instead.
        predicted_latency: f64,
        /// Prompt tokens of this step served from the prefix cache
        /// (DESIGN.md §3.7) — prefill work the perf model did *not* price
        /// because the KV is already resident. Always 0 for decode and
        /// warm steps.
        cached_tokens: usize,
        /// Step sequence id; stale completions are ignored by the core.
        seq: u64,
    },
    /// An online arrival truncated the running offline prefill on relaxed
    /// instance `inst` at the next layer boundary (§3.4.1). The executor
    /// must deliver the step's `on_step_end(inst, seq)` after `delay`
    /// instead of at the originally scheduled end.
    Preempt { inst: usize, delay: f64, seq: u64 },
    /// A request's KV was dropped to make room; it re-enters its queue
    /// for recompute (offline work returns to the backlog; an online
    /// mid-prefill resident requeued to break a chunked-admission
    /// overcommit returns to the head of its online queue — DESIGN.md
    /// §3.8). Executors holding real KV buffers free them.
    Evict { inst: InstanceRef, req: RequestId },
    /// Algorithm 1 pull: `req`'s offline decode moves from a relaxed to a
    /// strict instance. Always followed by the matching
    /// [`Action::TransferStart`].
    Migrate {
        req: RequestId,
        from_relaxed: usize,
        to_strict: usize,
    },
    /// A transfer job for `req`'s `kv_tokens`-sized KV cache entered the
    /// transport subsystem (notification). Executors holding real KV
    /// allocate the `chunks`-chunk staging for the copy; the timed work
    /// arrives as [`Action::TransferChunk`] orders.
    TransferStart {
        job: JobId,
        req: RequestId,
        kind: TransferKind,
        kv_tokens: usize,
        chunks: usize,
    },
    /// Work order: chunk `chunk` of `job` occupies `link` for
    /// `predicted_latency` seconds. The executor must invoke
    /// [`super::SchedulerCore::on_transfer_progress`] with (`job`, `seq`)
    /// once it has elapsed — and, on a real substrate, actually copy the
    /// chunk's KV range.
    TransferChunk {
        job: JobId,
        req: RequestId,
        link: usize,
        chunk: usize,
        predicted_latency: f64,
        seq: u64,
    },
    /// `job`'s final chunk landed and `req`'s KV residency was handed off
    /// (notification). Executors swap their staging copy in.
    TransferDone {
        job: JobId,
        req: RequestId,
        kind: TransferKind,
    },
    /// `job` was aborted mid-flight — its destination reservation was
    /// released and `req` falls back to discard-and-recompute (always
    /// followed by the matching [`Action::Evict`]). Executors drop the
    /// staging copy.
    TransferCancel { job: JobId, req: RequestId },
    /// The gating cost model (§3.4.2) admitted an offline request for
    /// (re-)prefill on relaxed instance `inst`.
    Admit { inst: usize, req: RequestId },
    /// The prefix cache (DESIGN.md §3.7) was consulted for `req`'s
    /// declared shared prefix at prefill admission (notification).
    /// `cached_tokens > 0` is a hit (that many prompt tokens need no
    /// recompute); 0 is a miss. Part of the differential action stream, so
    /// both executors must resolve identically.
    PrefixResolve {
        inst: InstanceRef,
        req: RequestId,
        /// Prompt tokens served from cache-resident blocks.
        cached_tokens: usize,
        /// Cache entries referenced (full blocks + a copy-on-write
        /// partial, when present).
        cached_blocks: usize,
    },
    /// `blocks` reclaimable prefix-cache blocks on `inst` were evicted
    /// (LRU reclaim by an admission, or a drain purge) and their chain
    /// entries dropped (notification).
    PrefixEvict { inst: InstanceRef, blocks: usize },
    /// The elastic pool manager re-planned the strict/relaxed split
    /// (notification; `epoch` is the monotone plan counter). Targets always
    /// satisfy `relaxed_target + strict_target ==` current cluster size —
    /// repartitioning repurposes instances, it never adds or removes them.
    RepartitionPlan {
        epoch: u64,
        relaxed_current: usize,
        strict_current: usize,
        relaxed_target: usize,
        strict_target: usize,
    },
    /// A role transition advanced (notification). `inst` names the
    /// instance in the pool it belongs to *when the action is emitted*:
    /// its old pool for [`RolePhase::Drain`], its new pool for
    /// [`RolePhase::Flip`] and [`RolePhase::Warm`]. `to` is the role the
    /// instance is moving to (constant across the three phases). The timed
    /// warm-up itself arrives as an ordinary [`Action::StartStep`] with
    /// [`StepKind::Warm`], so executors need no extra work-order type.
    RoleChange {
        phase: RolePhase,
        inst: InstanceRef,
        to: PoolRole,
    },
    /// `req` produced its final token (or was sacrificed under
    /// [`crate::coordinator::OverloadMode::Shed`]) and left the cluster.
    Complete { req: RequestId },
    /// Fleet fault model (DESIGN.md §3.9): `inst` crashed, losing its KV
    /// and the running step (notification). Every eviction the crash
    /// forces arrives as an ordinary [`Action::Evict`]; executors holding
    /// real resources tear down the instance's buffers.
    InstanceDown { inst: InstanceRef },
    /// `inst` recovered and rejoined its pool empty (notification).
    InstanceUp { inst: InstanceRef },
}

impl Action {
    /// Request this action is primarily about, when it names one.
    pub fn request(&self) -> Option<RequestId> {
        match self {
            Action::StartStep { .. } => None,
            Action::Preempt { .. } => None,
            Action::RepartitionPlan { .. } => None,
            Action::RoleChange { .. } => None,
            Action::PrefixEvict { .. } => None,
            Action::InstanceDown { .. } => None,
            Action::InstanceUp { .. } => None,
            Action::Evict { req, .. }
            | Action::Migrate { req, .. }
            | Action::TransferStart { req, .. }
            | Action::TransferChunk { req, .. }
            | Action::TransferDone { req, .. }
            | Action::TransferCancel { req, .. }
            | Action::Admit { req, .. }
            | Action::PrefixResolve { req, .. }
            | Action::Complete { req } => Some(*req),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_extraction() {
        assert_eq!(Action::Complete { req: 7 }.request(), Some(7));
        assert_eq!(
            Action::Evict {
                inst: InstanceRef::Strict(0),
                req: 3
            }
            .request(),
            Some(3)
        );
        assert_eq!(
            Action::TransferChunk {
                job: 1,
                req: 9,
                link: 0,
                chunk: 2,
                predicted_latency: 0.01,
                seq: 5
            }
            .request(),
            Some(9)
        );
        let step = Action::StartStep {
            inst: InstanceRef::Relaxed(1),
            kind: StepKind::PrefillOnline,
            participants: vec![1, 2],
            prefill: vec![PrefillSegment {
                req: 3,
                tokens: 256,
                last: true,
            }],
            predicted_latency: 0.5,
            cached_tokens: 0,
            seq: 4,
        };
        assert_eq!(step.request(), None);
        assert_eq!(
            Action::PrefixResolve {
                inst: InstanceRef::Relaxed(0),
                req: 6,
                cached_tokens: 32,
                cached_blocks: 2,
            }
            .request(),
            Some(6)
        );
        assert_eq!(
            Action::PrefixEvict {
                inst: InstanceRef::Relaxed(0),
                blocks: 3
            }
            .request(),
            None
        );
        // Pool-manager actions are cluster-level, not per-request.
        let plan = Action::RepartitionPlan {
            epoch: 1,
            relaxed_current: 2,
            strict_current: 2,
            relaxed_target: 1,
            strict_target: 3,
        };
        assert_eq!(plan.request(), None);
        let role = Action::RoleChange {
            phase: RolePhase::Drain,
            inst: InstanceRef::Relaxed(1),
            to: PoolRole::Strict,
        };
        assert_eq!(role.request(), None);
    }
}
